//! Model registry: named SVD-reparameterized weights plus the execution
//! engine that serves them. Models are either square ([`SvdParam`]) or
//! rectangular ([`RectSvdParam`] with an optional served rank) — the
//! registry partition owned by each shard holds [`ModelState`]s of both.

use super::sync::{read_or_recover, write_or_recover};
use crate::linalg::Mat;
use crate::runtime::pjrt::{ArtifactEngine, Tensor};
use crate::svd::approx::{randomized_svd, FnOp, LowRank, SketchConfig};
use crate::svd::rect::RectSvdParam;
use crate::svd::{MatrixOp, SvdParam};
use crate::util::Rng;
use anyhow::{anyhow, bail, Result};
use std::collections::{BTreeMap, VecDeque};
use std::sync::{Arc, Mutex, RwLock};

use super::protocol::OpKind;

/// How batches for a model are executed.
#[derive(Clone)]
pub enum ExecEngine {
    /// Native Rust FastH with block size k.
    Native { k: usize },
    /// AOT artifact via PJRT (artifact names resolved as
    /// `svd_apply_{d}` / `svd_inverse_{d}` from the shared engine).
    Pjrt(Arc<ArtifactEngine>),
}

/// The served parameterization: square or rectangular `U·Σ·Vᵀ`.
pub enum ModelEntry {
    /// `d×d` with full Table-1 op coverage.
    Square(SvdParam),
    /// `rows×cols` serving `apply` / `pinv`, optionally rank-truncated
    /// (§2.1 low-rank route: σ beyond the top `rank` zeroed at load).
    Rect {
        param: RectSvdParam,
        /// Served rank `r ≤ min(rows, cols)`.
        rank: usize,
    },
}

/// One served model.
pub struct ModelState {
    pub name: String,
    pub entry: ModelEntry,
    pub engine: ExecEngine,
}

impl ModelState {
    /// The square parameterization, if this model is square.
    pub fn square(&self) -> Option<&SvdParam> {
        match &self.entry {
            ModelEntry::Square(p) => Some(p),
            ModelEntry::Rect { .. } => None,
        }
    }

    /// `(input dim, output dim)` of `op` on this model — the protocol's
    /// ragged-width contract (a rect `apply` takes `cols`-vectors and
    /// returns `rows`-vectors; `pinv` the reverse). Errors on ops the
    /// shape does not support.
    pub fn dims(&self, op: OpKind) -> Result<(usize, usize)> {
        match &self.entry {
            ModelEntry::Square(p) => Ok((p.dim(), p.dim())),
            ModelEntry::Rect { param, .. } => match op {
                OpKind::Apply => Ok((param.cols, param.rows)),
                OpKind::Pinv => Ok((param.rows, param.cols)),
                OpKind::Inverse | OpKind::Expm | OpKind::Cayley => bail!(
                    "op '{}' needs a square model; '{}' is {}×{} (use apply/pinv)",
                    op.name(),
                    self.name,
                    param.rows,
                    param.cols
                ),
            },
        }
    }

    /// Rank-aware [`Self::dims`] (the per-request `rank` knob's
    /// validation point). Truncation changes the *content* of a frame,
    /// never its widths — a rank-`r` rect `pinv` still returns
    /// `cols`-vectors, just confined to the top-`r` right singular
    /// subspace — but a reduced rank is only meaningful on `apply` /
    /// `pinv`, and `r` must fit the spectrum. Previously the worker had
    /// no rank-aware dims query at all, so a truncated rect `pinv`
    /// could not validate the frame it was about to assemble.
    pub fn dims_at(&self, op: OpKind, rank: Option<usize>) -> Result<(usize, usize)> {
        let dims = self.dims(op)?;
        if let Some(r) = rank {
            if !matches!(op, OpKind::Apply | OpKind::Pinv) {
                bail!(
                    "op '{}' does not accept a truncation rank (apply/pinv only)",
                    op.name()
                );
            }
            let full = self.min_dim();
            if r == 0 || r > full {
                bail!("rank {r} out of range for model '{}' (1..={full})", self.name);
            }
        }
        Ok(dims)
    }

    /// min(rows, cols) — the length of the model's spectrum, the upper
    /// bound on any truncation rank.
    pub fn min_dim(&self) -> usize {
        match &self.entry {
            ModelEntry::Square(p) => p.dim(),
            ModelEntry::Rect { param, .. } => param.rows.min(param.cols),
        }
    }

    /// The weight as an abstract [`LinOp`](crate::svd::approx::LinOp):
    /// forward and transpose products through the Householder factors,
    /// never materializing `W`. This is what the randomized range-finder
    /// sketches — `O(d²)` per probe instead of an `O(d³)` densification.
    /// PJRT-engined models sketch through their native factors (the
    /// param is always resident; only batch execution is offloaded).
    pub fn as_linop(&self) -> FnOp<'_> {
        use crate::householder::fasth;
        match &self.entry {
            ModelEntry::Square(p) => {
                let d = p.dim();
                let k = self.native_k().clamp(1, d.max(1));
                FnOp::new(
                    d,
                    d,
                    move |x| p.apply(x, k),
                    // Wᵀ = V·Σ·Uᵀ (Σ symmetric in the square case).
                    move |x| {
                        let y = fasth::fasth_apply_transpose(&p.u, x, k);
                        let y = crate::svd::param::scale_rows(&y, &p.sigma);
                        fasth::fasth_apply(&p.v, &y, k)
                    },
                )
            }
            ModelEntry::Rect { param, .. } => {
                let (n, m) = (param.rows, param.cols);
                let k = self.native_k();
                FnOp::new(
                    n,
                    m,
                    move |x| param.apply(x, k),
                    // Wᵀ = V·Σᵀ·Uᵀ: the Σᵀ step reshapes n → m rows.
                    move |y| {
                        let y1 =
                            fasth::fasth_apply_transpose(&param.u, y, k.clamp(1, n.max(1)));
                        let y2 = sigma_t_scale(&param.sigma, &y1, m);
                        fasth::fasth_apply(&param.v, &y2, k.clamp(1, m.max(1)))
                    },
                )
            }
        }
    }

    fn native_k(&self) -> usize {
        match &self.engine {
            ExecEngine::Native { k } => *k,
            ExecEngine::Pjrt(_) => 16,
        }
    }

    /// Execute `op` on a batch whose width is the op's input dim.
    pub fn execute(&self, op: OpKind, x: &Mat) -> Result<Mat> {
        let (d_in, _d_out) = self.dims(op)?;
        if x.rows() != d_in {
            bail!(
                "model '{}' expects {d_in}-rows input for '{}', got {} rows",
                self.name,
                op.name(),
                x.rows()
            );
        }
        match &self.entry {
            ModelEntry::Square(p) => self.execute_square(p, op, x),
            ModelEntry::Rect { param, .. } => match &self.engine {
                ExecEngine::Native { k } => Ok(match op {
                    OpKind::Apply => param.apply(x, *k),
                    OpKind::Pinv => param.apply_pinv(x, *k),
                    _ => unreachable!("dims() rejected non-rect ops"),
                }),
                ExecEngine::Pjrt(_) => bail!(
                    "rect model '{}' has no AOT artifacts; serve it natively",
                    self.name
                ),
            },
        }
    }

    fn execute_square(&self, p: &SvdParam, op: OpKind, x: &Mat) -> Result<Mat> {
        let d = p.dim();
        match &self.engine {
            ExecEngine::Native { k } => Ok(match op {
                OpKind::Apply => p.apply(x, *k),
                OpKind::Inverse => p.apply_inverse(x, *k),
                // Moore-Penrose on the square route: Σ⁺ zeroes the σ = 0
                // directions where apply_inverse would emit ∞ (equal to
                // Inverse whenever σ ≠ 0, e.g. every create()d model).
                OpKind::Pinv => {
                    let pinv: Vec<f32> = p.sigma.iter().map(|&s| recip_or_zero(s)).collect();
                    inverse_with_sigma(p, &pinv, x, *k)
                }
                OpKind::Expm => {
                    let sig = MatrixOp::Expm.transform_sigma(&p.sigma);
                    apply_with_sigma(p, &sig, x, *k)
                }
                OpKind::Cayley => {
                    let sig = MatrixOp::Cayley.transform_sigma(&p.sigma);
                    apply_with_sigma(p, &sig, x, *k)
                }
            }),
            ExecEngine::Pjrt(engine) => {
                // Artifacts exist for apply/inverse; expm/cayley reuse the
                // apply artifact with a transformed spectrum (identical
                // graph, different σ input — Table 1's point).
                let (artifact, sigma) = match op {
                    OpKind::Apply => (format!("svd_apply_{d}"), p.sigma.clone()),
                    OpKind::Inverse | OpKind::Pinv => {
                        // The inverse artifact reciprocates σ in-graph, so
                        // it cannot express Σ⁺'s zero-stays-zero rule.
                        if op == OpKind::Pinv && p.sigma.iter().any(|s| s.abs() < 1e-30) {
                            bail!("model '{}' has σ = 0: pinv needs the native engine", self.name);
                        }
                        (format!("svd_inverse_{d}"), p.sigma.clone())
                    }
                    OpKind::Expm => (
                        format!("svd_apply_{d}"),
                        MatrixOp::Expm.transform_sigma(&p.sigma),
                    ),
                    OpKind::Cayley => (
                        format!("svd_apply_{d}"),
                        MatrixOp::Cayley.transform_sigma(&p.sigma),
                    ),
                };
                let entry = engine
                    .entry(&artifact)
                    .ok_or_else(|| anyhow!("no artifact '{artifact}' for model '{}'", self.name))?;
                // Artifacts are lowered for a fixed batch m: wider batches
                // run in m-column chunks (never truncate), narrower ones
                // zero-pad. The U/V/σ tensors are built once; only the
                // chunk slot changes per call.
                let m_art = entry.m;
                let mut inputs = vec![
                    Tensor::M(p.u.v.clone()),
                    Tensor::M(p.v.v.clone()),
                    Tensor::V(sigma),
                    Tensor::M(Mat::zeros(0, 0)),
                ];
                run_in_col_chunks(x, m_art, |chunk| {
                    inputs[3] = Tensor::M(chunk);
                    engine.run1(&artifact, &inputs)
                })
            }
        }
    }
}

/// `L·diag(σ')·Rᵀ` application reusing the param's factors with a
/// transformed spectrum (expm/cayley serving route).
fn apply_with_sigma(p: &SvdParam, sigma: &[f32], x: &Mat, k: usize) -> Mat {
    use crate::householder::fasth;
    let x1 = fasth::fasth_apply_transpose(&p.v, x, k);
    let x2 = crate::svd::param::scale_rows(&x1, sigma);
    fasth::fasth_apply(&p.u, &x2, k)
}

/// `V·diag(σ')·Uᵀ` — the inverse-direction route with a caller-supplied
/// (already reciprocated) spectrum (the square pinv path).
fn inverse_with_sigma(p: &SvdParam, sigma: &[f32], x: &Mat, k: usize) -> Mat {
    use crate::householder::fasth;
    let y1 = fasth::fasth_apply_transpose(&p.u, x, k);
    let y2 = crate::svd::param::scale_rows(&y1, sigma);
    fasth::fasth_apply(&p.v, &y2, k)
}

/// `Σᵀ·Y` for a rectangular-diagonal `Σ`: scale the first min(n, m)
/// rows by σ, reshaped to `out_rows` (the adjoint of the Σ inside
/// `RectSvdParam::apply`, used by the sketch's transpose product).
fn sigma_t_scale(sigma: &[f32], y: &Mat, out_rows: usize) -> Mat {
    let mut out = Mat::zeros(out_rows, y.cols());
    for i in 0..sigma.len().min(out_rows).min(y.rows()) {
        let s = sigma[i];
        let src = y.row(i);
        let dst = out.row_mut(i);
        for (d, &v) in dst.iter_mut().zip(src) {
            *d = s * v;
        }
    }
    out
}

/// `1/σ`, except Σ⁺'s convention that a zero singular value contributes
/// zero (matches `RectSvdParam::sigma_pinv_apply`).
fn recip_or_zero(s: f32) -> f32 {
    if s.abs() < 1e-30 {
        0.0
    } else {
        1.0 / s
    }
}

/// Pad (or truncate) a batch to exactly `m` columns with zeros.
fn pad_cols(x: &Mat, m: usize) -> Mat {
    if x.cols() == m {
        return x.clone();
    }
    let mut out = Mat::zeros(x.rows(), m);
    for i in 0..x.rows() {
        for j in 0..x.cols().min(m) {
            out[(i, j)] = x[(i, j)];
        }
    }
    out
}

/// Run a fixed-width executor over an arbitrary-width batch: `x` is
/// split into `≤ m_art`-column chunks, each zero-padded to exactly
/// `m_art` columns, and the outputs are reassembled at `x.cols()` width.
/// (Regression shield: the old path padded *or truncated* to one
/// artifact batch and then sliced `x.cols()` columns out of the `m_art`
/// -wide result — reading past the artifact's output for wide batches.)
fn run_in_col_chunks(
    x: &Mat,
    m_art: usize,
    mut run: impl FnMut(Mat) -> Result<Mat>,
) -> Result<Mat> {
    assert!(m_art > 0, "artifact batch width must be positive");
    let mut out: Option<Mat> = None;
    for c0 in (0..x.cols()).step_by(m_art) {
        let c1 = (c0 + m_art).min(x.cols());
        let chunk = x.slice(0, x.rows(), c0, c1);
        let y = run(pad_cols(&chunk, m_art))?;
        if y.cols() != m_art {
            bail!("executor returned {} columns, expected {m_art}", y.cols());
        }
        let dst = out.get_or_insert_with(|| Mat::zeros(y.rows(), x.cols()));
        dst.set_slice(0, c0, &y.slice(0, y.rows(), 0, c1 - c0));
    }
    Ok(out.unwrap_or_else(|| Mat::zeros(x.rows(), 0)))
}

/// Bound on distinct `(model, rank)` truncations kept resident per
/// registry partition; beyond it the least-recently-served truncation
/// is dropped (it re-sketches deterministically on next use).
const LOWRANK_CAP: usize = 32;

/// LRU of sketched truncations, shared by every worker on the shard.
#[derive(Default)]
struct LowRankCache {
    map: BTreeMap<(String, usize), Arc<LowRank>>,
    lru: VecDeque<(String, usize)>,
}

impl LowRankCache {
    /// Move `key` to most-recently-used.
    fn touch(&mut self, key: &(String, usize)) {
        if let Some(pos) = self.lru.iter().position(|k| k == key) {
            self.lru.remove(pos);
        }
        self.lru.push_back(key.clone());
    }

    fn insert(&mut self, key: (String, usize), lr: Arc<LowRank>) {
        self.map.insert(key.clone(), lr);
        self.touch(&key);
        while self.map.len() > LOWRANK_CAP {
            let Some(victim) = self.lru.pop_front() else { break };
            self.map.remove(&victim);
        }
    }
}

/// Deterministic Ω seed per (model, rank): FNV-1a over the name, rank
/// folded in — every shard and restart sketches the same truncation.
fn lowrank_seed(name: &str, rank: usize) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h ^ rank as u64
}

/// Thread-safe registry of served models. The server partitions one
/// registry per shard (rendezvous-hashed on model name); this type is
/// both the user-facing catalog and the per-shard partition. It also
/// owns the shard's [`LowRank`] truncation cache (per-request `rank`
/// serving), so cached sketches live and die with their models.
pub struct ModelRegistry {
    models: RwLock<BTreeMap<String, Arc<ModelState>>>,
    lowrank: Mutex<LowRankCache>,
}

impl Default for ModelRegistry {
    fn default() -> Self {
        Self::new()
    }
}

impl ModelRegistry {
    pub fn new() -> ModelRegistry {
        ModelRegistry {
            models: RwLock::new(BTreeMap::new()),
            lowrank: Mutex::new(LowRankCache::default()),
        }
    }

    /// The rank-`r` truncation of model `name`, sketched on first use
    /// via the randomized range-finder and cached (bounded LRU,
    /// [`LOWRANK_CAP`] entries). Returns the factorization and whether
    /// the lookup hit the cache. Building happens under the cache lock
    /// so a cold rank is sketched exactly once even when many requests
    /// race for it; exact (rank-absent) traffic never touches the lock.
    pub fn lowrank(&self, name: &str, rank: usize) -> Result<(Arc<LowRank>, bool)> {
        let state =
            self.get(name).ok_or_else(|| anyhow!("unknown model '{name}'"))?;
        state.dims_at(OpKind::Apply, Some(rank))?;
        let key = (name.to_string(), rank);
        let mut cache = self.lowrank.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(lr) = cache.map.get(&key).cloned() {
            cache.touch(&key);
            return Ok((lr, true));
        }
        let mut rng = Rng::new(lowrank_seed(name, rank));
        let op = state.as_linop();
        // A cold-rank sketch inside a traced batch is exec time largely
        // invisible to the GEMM counters (power iteration glue, small
        // factorizations), so attribute the whole build to the kernel
        // bucket — its inner GEMMs overlap the same window, which is
        // fine: these numbers are attribution, not billing.
        let t_sketch = crate::obs::compute_active().then(std::time::Instant::now);
        let lr = Arc::new(randomized_svd(&op, rank, &SketchConfig::default(), &mut rng));
        if let Some(t) = t_sketch {
            crate::obs::add_kernel_ns(t.elapsed().as_nanos() as u64);
        }
        cache.insert(key, Arc::clone(&lr));
        Ok((lr, false))
    }

    /// Resident truncation count (tests, stats).
    pub fn lowrank_cached(&self) -> usize {
        self.lowrank.lock().unwrap_or_else(|e| e.into_inner()).map.len()
    }

    /// Register a freshly initialized square model of size d.
    pub fn create(&self, name: &str, d: usize, engine: ExecEngine, seed: u64) {
        let mut rng = Rng::new(seed);
        let mut param = SvdParam::random_full(d, &mut rng);
        // A generic non-unit spectrum keeps all ops interesting.
        for s in param.sigma.iter_mut() {
            *s = 0.75 + 0.5 * rng.uniform() as f32;
        }
        self.insert(name, param, engine);
    }

    /// Register a freshly initialized `rows×cols` rectangular model,
    /// optionally truncated to rank `r` (§2.1 low-rank serving).
    pub fn create_rect(
        &self,
        name: &str,
        rows: usize,
        cols: usize,
        rank: Option<usize>,
        engine: ExecEngine,
        seed: u64,
    ) {
        let mut rng = Rng::new(seed);
        let mut param = RectSvdParam::random(rows, cols, &mut rng);
        for s in param.sigma.iter_mut() {
            *s = 0.75 + 0.5 * rng.uniform() as f32;
        }
        self.insert_rect(name, param, rank, engine);
    }

    /// Register an existing square parameterization.
    pub fn insert(&self, name: &str, param: SvdParam, engine: ExecEngine) {
        let entry = ModelEntry::Square(param);
        self.insert_state(Arc::new(ModelState { name: name.to_string(), entry, engine }));
    }

    /// Register an existing rectangular parameterization, truncating to
    /// `rank` if given.
    pub fn insert_rect(
        &self,
        name: &str,
        mut param: RectSvdParam,
        rank: Option<usize>,
        engine: ExecEngine,
    ) {
        let full = param.sigma.len();
        let rank = rank.unwrap_or(full).min(full);
        if rank < full {
            param.truncate_rank(rank);
        }
        let entry = ModelEntry::Rect { param, rank };
        self.insert_state(Arc::new(ModelState { name: name.to_string(), entry, engine }));
    }

    /// Register a pre-built model state (shard partitioning path).
    pub fn insert_state(&self, state: Arc<ModelState>) {
        write_or_recover(&self.models).insert(state.name.clone(), state);
    }

    pub fn get(&self, name: &str) -> Option<Arc<ModelState>> {
        read_or_recover(&self.models).get(name).cloned()
    }

    pub fn names(&self) -> Vec<String> {
        read_or_recover(&self.models).keys().cloned().collect()
    }

    pub fn len(&self) -> usize {
        read_or_recover(&self.models).len()
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::assert_close;

    #[test]
    fn registry_basics() {
        let reg = ModelRegistry::new();
        assert!(reg.is_empty());
        reg.create("svd_16", 16, ExecEngine::Native { k: 4 }, 1);
        assert_eq!(reg.len(), 1);
        assert!(reg.get("svd_16").is_some());
        assert!(reg.get("nope").is_none());
        assert_eq!(reg.names(), vec!["svd_16".to_string()]);
    }

    #[test]
    fn native_apply_then_inverse_roundtrips() {
        let reg = ModelRegistry::new();
        reg.create("m", 12, ExecEngine::Native { k: 4 }, 2);
        let model = reg.get("m").unwrap();
        let mut rng = Rng::new(3);
        let x = Mat::randn(12, 5, &mut rng);
        let y = model.execute(OpKind::Apply, &x).unwrap();
        for op in [OpKind::Inverse, OpKind::Pinv] {
            let back = model.execute(op, &y).unwrap();
            assert_close(back.data(), x.data(), 1e-2, 1e-2).unwrap();
        }
    }

    #[test]
    fn expm_cayley_native_run() {
        let reg = ModelRegistry::new();
        reg.create("m", 8, ExecEngine::Native { k: 4 }, 4);
        let model = reg.get("m").unwrap();
        let mut rng = Rng::new(5);
        let x = Mat::randn(8, 3, &mut rng);
        for op in [OpKind::Expm, OpKind::Cayley] {
            let y = model.execute(op, &x).unwrap();
            assert!(!y.has_non_finite());
            assert_eq!((y.rows(), y.cols()), (8, 3));
        }
    }

    #[test]
    fn dimension_mismatch_is_error() {
        let reg = ModelRegistry::new();
        reg.create("m", 8, ExecEngine::Native { k: 4 }, 6);
        let model = reg.get("m").unwrap();
        let x = Mat::zeros(9, 2);
        assert!(model.execute(OpKind::Apply, &x).is_err());
    }

    #[test]
    fn rect_apply_pinv_roundtrip_and_dims() {
        let reg = ModelRegistry::new();
        reg.create_rect("r", 12, 7, None, ExecEngine::Native { k: 4 }, 7);
        let model = reg.get("r").unwrap();
        assert!(model.square().is_none());
        assert_eq!(model.dims(OpKind::Apply).unwrap(), (7, 12));
        assert_eq!(model.dims(OpKind::Pinv).unwrap(), (12, 7));
        assert!(model.dims(OpKind::Inverse).is_err());
        assert!(model.dims(OpKind::Expm).is_err());
        let mut rng = Rng::new(8);
        let x = Mat::randn(7, 3, &mut rng);
        let y = model.execute(OpKind::Apply, &x).unwrap();
        assert_eq!((y.rows(), y.cols()), (12, 3));
        // Tall full-rank: W⁺·W = I, so pinv round-trips.
        let back = model.execute(OpKind::Pinv, &y).unwrap();
        assert_close(back.data(), x.data(), 1e-2, 1e-2).unwrap();
        // Wrong-width input rejected, square-only ops rejected.
        assert!(model.execute(OpKind::Apply, &Mat::zeros(12, 2)).is_err());
        assert!(model.execute(OpKind::Expm, &Mat::zeros(7, 2)).is_err());
    }

    #[test]
    fn square_pinv_zeroes_dead_directions() {
        // insert() accepts any spectrum — a σ = 0 entry must make pinv
        // project (finite output), where inverse would divide by zero.
        let mut rng = Rng::new(14);
        let mut param = SvdParam::random_full(8, &mut rng);
        param.sigma[3] = 0.0;
        let reg = ModelRegistry::new();
        reg.insert("sq0", param, ExecEngine::Native { k: 4 });
        let model = reg.get("sq0").unwrap();
        let x = Mat::randn(8, 2, &mut rng);
        let y = model.execute(OpKind::Pinv, &x).unwrap();
        assert!(!y.has_non_finite(), "pinv must zero the σ = 0 direction");
    }

    #[test]
    fn rect_rank_truncation_applied_at_insert() {
        let reg = ModelRegistry::new();
        reg.create_rect("r", 10, 10, Some(3), ExecEngine::Native { k: 4 }, 9);
        let model = reg.get("r").unwrap();
        match &model.entry {
            ModelEntry::Rect { param, rank } => {
                assert_eq!(*rank, 3);
                assert_eq!(param.rank(), 3);
            }
            ModelEntry::Square(_) => panic!("expected rect"),
        }
        // Truncated-rank apply stays well-defined (a projection).
        let mut rng = Rng::new(10);
        let x = Mat::randn(10, 2, &mut rng);
        let y = model.execute(OpKind::Apply, &x).unwrap();
        assert!(!y.has_non_finite());
    }

    #[test]
    fn pad_cols_behaviour() {
        let x = Mat::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let p = pad_cols(&x, 4);
        assert_eq!((p.rows(), p.cols()), (2, 4));
        assert_eq!(p[(1, 1)], 4.0);
        assert_eq!(p[(0, 3)], 0.0);
        let t = pad_cols(&x, 1);
        assert_eq!((t.rows(), t.cols()), (2, 1));
        assert_eq!(t[(1, 0)], 3.0);
    }

    #[test]
    fn oversize_batch_runs_in_chunks() {
        // Regression: x wider than the artifact batch must chunk, not
        // truncate. Fake executor doubles values and asserts every chunk
        // arrives at exactly the artifact width.
        use std::sync::atomic::{AtomicUsize, Ordering};
        let m_art = 4usize;
        let mut rng = Rng::new(11);
        let x = Mat::randn(3, 2 * m_art + 3, &mut rng); // ragged tail
        let calls = AtomicUsize::new(0);
        let y = run_in_col_chunks(&x, m_art, |chunk| {
            calls.fetch_add(1, Ordering::Relaxed);
            assert_eq!(chunk.cols(), m_art);
            Ok(chunk.map(|v| 2.0 * v))
        })
        .unwrap();
        assert_eq!(calls.load(Ordering::Relaxed), 3);
        assert_eq!((y.rows(), y.cols()), (3, x.cols()));
        for i in 0..3 {
            for j in 0..x.cols() {
                assert_eq!(y[(i, j)], 2.0 * x[(i, j)], "({i},{j})");
            }
        }
        // Narrow batches still pad up and slice back down.
        let narrow = Mat::randn(3, 2, &mut rng);
        let y2 = run_in_col_chunks(&narrow, m_art, |chunk| Ok(chunk.map(|v| v + 1.0))).unwrap();
        assert_eq!((y2.rows(), y2.cols()), (3, 2));
        assert_eq!(y2[(2, 1)], narrow[(2, 1)] + 1.0);
        // Executor errors surface.
        assert!(run_in_col_chunks(&narrow, m_art, |_| anyhow::bail!("boom")).is_err());
    }

    #[test]
    fn dims_at_validates_rank() {
        let reg = ModelRegistry::new();
        reg.create("sq", 8, ExecEngine::Native { k: 4 }, 30);
        reg.create_rect("rc", 12, 7, None, ExecEngine::Native { k: 4 }, 31);
        let sq = reg.get("sq").unwrap();
        let rc = reg.get("rc").unwrap();
        // rank=None is exactly dims().
        assert_eq!(sq.dims_at(OpKind::Apply, None).unwrap(), (8, 8));
        assert_eq!(rc.dims_at(OpKind::Pinv, None).unwrap(), (12, 7));
        // Truncation preserves frame widths.
        assert_eq!(sq.dims_at(OpKind::Apply, Some(3)).unwrap(), (8, 8));
        assert_eq!(rc.dims_at(OpKind::Pinv, Some(4)).unwrap(), (12, 7));
        assert_eq!(rc.min_dim(), 7);
        // Out-of-range ranks and rank on square-only ops rejected.
        assert!(sq.dims_at(OpKind::Apply, Some(0)).is_err());
        assert!(sq.dims_at(OpKind::Apply, Some(9)).is_err());
        assert!(rc.dims_at(OpKind::Apply, Some(8)).is_err());
        assert!(sq.dims_at(OpKind::Inverse, Some(3)).is_err());
        assert!(sq.dims_at(OpKind::Expm, Some(3)).is_err());
    }

    #[test]
    fn as_linop_transpose_is_adjoint() {
        // <W·x, y> = <x, Wᵀ·y> for both shapes — validates the sketch's
        // transpose route through the Householder factors.
        let reg = ModelRegistry::new();
        reg.create("sq", 10, ExecEngine::Native { k: 4 }, 32);
        reg.create_rect("rc", 11, 6, None, ExecEngine::Native { k: 4 }, 33);
        let mut rng = Rng::new(34);
        for name in ["sq", "rc"] {
            let model = reg.get(name).unwrap();
            let op = model.as_linop();
            use crate::svd::approx::LinOp;
            let x = Mat::randn(op.cols(), 3, &mut rng);
            let y = Mat::randn(op.rows(), 3, &mut rng);
            let wx = op.apply(&x);
            let wty = op.apply_t(&y);
            let lhs: f64 =
                wx.data().iter().zip(y.data()).map(|(&a, &b)| a as f64 * b as f64).sum();
            let rhs: f64 =
                x.data().iter().zip(wty.data()).map(|(&a, &b)| a as f64 * b as f64).sum();
            assert!((lhs - rhs).abs() < 1e-3 * lhs.abs().max(1.0), "{name}: {lhs} vs {rhs}");
        }
    }

    #[test]
    fn lowrank_cache_hit_miss_and_validation() {
        let reg = ModelRegistry::new();
        reg.create("m", 16, ExecEngine::Native { k: 4 }, 35);
        let (lr, hit) = reg.lowrank("m", 4).unwrap();
        assert!(!hit, "first lookup must build");
        assert_eq!(lr.rank(), 4);
        let (lr2, hit2) = reg.lowrank("m", 4).unwrap();
        assert!(hit2, "second lookup must hit");
        assert!(Arc::ptr_eq(&lr, &lr2), "hit returns the cached Arc");
        assert_eq!(reg.lowrank_cached(), 1);
        assert!(reg.lowrank("m", 0).is_err());
        assert!(reg.lowrank("m", 17).is_err());
        assert!(reg.lowrank("nope", 4).is_err());
    }

    #[test]
    fn lowrank_full_rank_matches_exact_execution() {
        // At r = d the sketch spans the whole space, so the truncated
        // route must reproduce the exact engine (square and rect, both
        // directions).
        let reg = ModelRegistry::new();
        reg.create("sq", 12, ExecEngine::Native { k: 4 }, 36);
        reg.create_rect("rc", 12, 7, None, ExecEngine::Native { k: 4 }, 37);
        let mut rng = Rng::new(38);
        for (name, r) in [("sq", 12usize), ("rc", 7)] {
            let model = reg.get(name).unwrap();
            let (lr, _) = reg.lowrank(name, r).unwrap();
            let (d_in, d_out) = model.dims(OpKind::Apply).unwrap();
            let x = Mat::randn(d_in, 3, &mut rng);
            let y_exact = model.execute(OpKind::Apply, &x).unwrap();
            assert!(
                lr.apply(&x).max_abs_diff(&y_exact) < 1e-2,
                "{name} apply diff {}",
                lr.apply(&x).max_abs_diff(&y_exact)
            );
            let y = Mat::randn(d_out, 3, &mut rng);
            let back_exact = model.execute(OpKind::Pinv, &y).unwrap();
            assert!(
                lr.pinv(&y).max_abs_diff(&back_exact) < 1e-2,
                "{name} pinv diff {}",
                lr.pinv(&y).max_abs_diff(&back_exact)
            );
        }
    }

    #[test]
    fn lowrank_cache_evicts_least_recent() {
        let reg = ModelRegistry::new();
        reg.create("a", 33, ExecEngine::Native { k: 4 }, 39);
        // Fill the cache past its cap with distinct ranks.
        for r in 1..=33usize {
            reg.lowrank("a", r).unwrap();
        }
        assert_eq!(reg.lowrank_cached(), 32, "cap enforced");
        // rank=1 was the least-recently-used entry: it must have been
        // evicted, so looking it up again is a miss (deterministic
        // rebuild), while rank=33 is still resident.
        let (_, hit1) = reg.lowrank("a", 1).unwrap();
        assert!(!hit1);
        let (_, hit33) = reg.lowrank("a", 33).unwrap();
        assert!(hit33);
    }

    #[test]
    fn heuristic_k_used_somewhere() {
        // Document the link between registry defaults and §3.3 tuning.
        assert!(crate::householder::tune::KCache::heuristic(64, 32) >= 8);
    }
}
