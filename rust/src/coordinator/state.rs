//! Model registry: named SVD-reparameterized weights plus the execution
//! engine that serves them.

use crate::linalg::Mat;
use crate::runtime::pjrt::{ArtifactEngine, Tensor};
use crate::svd::{MatrixOp, SvdParam};
use crate::util::Rng;
use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::sync::{Arc, RwLock};

use super::protocol::OpKind;

/// How batches for a model are executed.
#[derive(Clone)]
pub enum ExecEngine {
    /// Native Rust FastH with block size k.
    Native { k: usize },
    /// AOT artifact via PJRT (artifact names resolved as
    /// `svd_apply_{d}` / `svd_inverse_{d}` from the shared engine).
    Pjrt(Arc<ArtifactEngine>),
}

/// One served model.
pub struct ModelState {
    pub name: String,
    pub param: SvdParam,
    pub engine: ExecEngine,
}

impl ModelState {
    /// Execute `op` on a d×m batch.
    pub fn execute(&self, op: OpKind, x: &Mat) -> Result<Mat> {
        let d = self.param.dim();
        if x.rows() != d {
            bail!("model '{}' is {d}-dimensional, got {} rows", self.name, x.rows());
        }
        match &self.engine {
            ExecEngine::Native { k } => Ok(match op {
                OpKind::Apply => self.param.apply(x, *k),
                OpKind::Inverse => self.param.apply_inverse(x, *k),
                OpKind::Expm => {
                    let sig = MatrixOp::Expm.transform_sigma(&self.param.sigma);
                    apply_with_sigma(&self.param, &sig, x, *k)
                }
                OpKind::Cayley => {
                    let sig = MatrixOp::Cayley.transform_sigma(&self.param.sigma);
                    apply_with_sigma(&self.param, &sig, x, *k)
                }
            }),
            ExecEngine::Pjrt(engine) => {
                // Artifacts exist for apply/inverse; expm/cayley reuse the
                // apply artifact with a transformed spectrum (identical
                // graph, different σ input — Table 1's point).
                let (artifact, sigma) = match op {
                    OpKind::Apply => (format!("svd_apply_{d}"), self.param.sigma.clone()),
                    OpKind::Inverse => {
                        (format!("svd_inverse_{d}"), self.param.sigma.clone())
                    }
                    OpKind::Expm => (
                        format!("svd_apply_{d}"),
                        MatrixOp::Expm.transform_sigma(&self.param.sigma),
                    ),
                    OpKind::Cayley => (
                        format!("svd_apply_{d}"),
                        MatrixOp::Cayley.transform_sigma(&self.param.sigma),
                    ),
                };
                let entry = engine
                    .entry(&artifact)
                    .ok_or_else(|| anyhow!("no artifact '{artifact}' for model '{}'", self.name))?;
                // Artifacts are lowered for a fixed batch m: pad/truncate.
                let m_art = entry.m;
                let x_padded = pad_cols(x, m_art);
                let out = engine.run1(
                    &artifact,
                    &[
                        Tensor::M(self.param.u.v.clone()),
                        Tensor::M(self.param.v.v.clone()),
                        Tensor::V(sigma),
                        Tensor::M(x_padded),
                    ],
                )?;
                Ok(out.slice(0, d, 0, x.cols()))
            }
        }
    }
}

/// `L·diag(σ')·Rᵀ` application reusing the param's factors with a
/// transformed spectrum (expm/cayley serving route).
fn apply_with_sigma(p: &SvdParam, sigma: &[f32], x: &Mat, k: usize) -> Mat {
    use crate::householder::fasth;
    let x1 = fasth::fasth_apply_transpose(&p.v, x, k);
    let x2 = crate::svd::param::scale_rows(&x1, sigma);
    fasth::fasth_apply(&p.u, &x2, k)
}

/// Pad (or truncate) a batch to exactly `m` columns with zeros.
fn pad_cols(x: &Mat, m: usize) -> Mat {
    if x.cols() == m {
        return x.clone();
    }
    let mut out = Mat::zeros(x.rows(), m);
    for i in 0..x.rows() {
        for j in 0..x.cols().min(m) {
            out[(i, j)] = x[(i, j)];
        }
    }
    out
}

/// Thread-safe registry of served models.
pub struct ModelRegistry {
    models: RwLock<BTreeMap<String, Arc<ModelState>>>,
}

impl Default for ModelRegistry {
    fn default() -> Self {
        Self::new()
    }
}

impl ModelRegistry {
    pub fn new() -> ModelRegistry {
        ModelRegistry { models: RwLock::new(BTreeMap::new()) }
    }

    /// Register a freshly initialized model of size d.
    pub fn create(&self, name: &str, d: usize, engine: ExecEngine, seed: u64) {
        let mut rng = Rng::new(seed);
        let mut param = SvdParam::random_full(d, &mut rng);
        // A generic non-unit spectrum keeps all ops interesting.
        for s in param.sigma.iter_mut() {
            *s = 0.75 + 0.5 * rng.uniform() as f32;
        }
        let state = ModelState { name: name.to_string(), param, engine };
        self.models.write().unwrap().insert(name.to_string(), Arc::new(state));
    }

    /// Register an existing parameterization.
    pub fn insert(&self, name: &str, param: SvdParam, engine: ExecEngine) {
        let state = ModelState { name: name.to_string(), param, engine };
        self.models.write().unwrap().insert(name.to_string(), Arc::new(state));
    }

    pub fn get(&self, name: &str) -> Option<Arc<ModelState>> {
        self.models.read().unwrap().get(name).cloned()
    }

    pub fn names(&self) -> Vec<String> {
        self.models.read().unwrap().keys().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.models.read().unwrap().len()
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::assert_close;

    #[test]
    fn registry_basics() {
        let reg = ModelRegistry::new();
        assert!(reg.is_empty());
        reg.create("svd_16", 16, ExecEngine::Native { k: 4 }, 1);
        assert_eq!(reg.len(), 1);
        assert!(reg.get("svd_16").is_some());
        assert!(reg.get("nope").is_none());
        assert_eq!(reg.names(), vec!["svd_16".to_string()]);
    }

    #[test]
    fn native_apply_then_inverse_roundtrips() {
        let reg = ModelRegistry::new();
        reg.create("m", 12, ExecEngine::Native { k: 4 }, 2);
        let model = reg.get("m").unwrap();
        let mut rng = Rng::new(3);
        let x = Mat::randn(12, 5, &mut rng);
        let y = model.execute(OpKind::Apply, &x).unwrap();
        let back = model.execute(OpKind::Inverse, &y).unwrap();
        assert_close(back.data(), x.data(), 1e-2, 1e-2).unwrap();
    }

    #[test]
    fn expm_cayley_native_run() {
        let reg = ModelRegistry::new();
        reg.create("m", 8, ExecEngine::Native { k: 4 }, 4);
        let model = reg.get("m").unwrap();
        let mut rng = Rng::new(5);
        let x = Mat::randn(8, 3, &mut rng);
        for op in [OpKind::Expm, OpKind::Cayley] {
            let y = model.execute(op, &x).unwrap();
            assert!(!y.has_non_finite());
            assert_eq!((y.rows(), y.cols()), (8, 3));
        }
    }

    #[test]
    fn dimension_mismatch_is_error() {
        let reg = ModelRegistry::new();
        reg.create("m", 8, ExecEngine::Native { k: 4 }, 6);
        let model = reg.get("m").unwrap();
        let x = Mat::zeros(9, 2);
        assert!(model.execute(OpKind::Apply, &x).is_err());
    }

    #[test]
    fn pad_cols_behaviour() {
        let x = Mat::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let p = pad_cols(&x, 4);
        assert_eq!((p.rows(), p.cols()), (2, 4));
        assert_eq!(p[(1, 1)], 4.0);
        assert_eq!(p[(0, 3)], 0.0);
        let t = pad_cols(&x, 1);
        assert_eq!((t.rows(), t.cols()), (2, 1));
        assert_eq!(t[(1, 0)], 3.0);
    }

    #[test]
    fn heuristic_k_used_somewhere() {
        // Document the link between registry defaults and §3.3 tuning.
        assert!(crate::householder::tune::KCache::heuristic(64, 32) >= 8);
    }
}
