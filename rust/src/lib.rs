//! # FastH — "What if Neural Networks had SVDs?" (NeurIPS 2020)
//!
//! A full-system reproduction of Mathiasen et al.'s FastH: keeping the SVD
//! `W = U Σ Vᵀ` of neural-network weights *by construction* (U, V as
//! products of Householder reflections), so that matrix inversion,
//! determinants, the matrix exponential and the Cayley transform drop from
//! `O(d³)` to `O(d²)`/`O(d)` — with FastH supplying the blocked
//! (WY-representation) Householder multiplication that makes the scheme
//! actually fast on parallel hardware.
//!
//! Layering (see DESIGN.md):
//! - [`util`] — offline-substrate utilities (RNG, threads, JSON, bench
//!   harness, property testing),
//! - [`linalg`] — from-scratch dense linear algebra (GEMM, LU, expm, QR),
//! - [`householder`] — the paper's algorithms: sequential & parallel
//!   baselines from Zhang et al. 2018 and FastH fwd/bwd (Algorithms 1–3),
//! - [`svd`] — the SVD reparameterization layer and Table-1 matrix ops,
//! - [`nn`] — minimal NN stack (MLP/RNN/flows + optimizers + tasks) for
//!   the end-to-end experiments,
//! - [`experiments`] — the declarative workload harness: multi-seed
//!   training runs, versioned RunRecord artifacts, Table-2 reports,
//! - [`runtime`] — PJRT loading/execution of JAX/Pallas AOT artifacts,
//! - [`coordinator`] — the serving layer: router, dynamic batcher, workers,
//! - [`obs`] — crate-wide tracing: stage spans, sampling, kernel attribution,
//! - [`bench_harness`] — regenerates every figure/table of the paper.

pub mod bench_harness;
pub mod coordinator;
pub mod experiments;
pub mod householder;
pub mod linalg;
pub mod nn;
pub mod obs;
pub mod runtime;
pub mod svd;
pub mod util;
