//! Integration tests for the unified `Layer`/`Params` trait API: the
//! rectangular `LinearSvd` gradcheck suite (tall, wide, and rank-
//! truncated shapes), `RectSvdParam::apply_pinv` round-trips at ragged
//! block sizes, optimizer key stability across sweeps, and the
//! Adam-timestep safety net.

use fasth::householder::HouseholderVectors;
use fasth::linalg::{oracle, Mat};
use fasth::nn::module::collect_grads;
use fasth::nn::{
    mse, Activation, Adam, Ctx, Layer, Optimizer, Params, RectLinearSvd, Sequential, Sgd,
};
use fasth::svd::RectSvdParam;
use fasth::util::prop::assert_close;
use fasth::util::Rng;

/// Analytic gradients of an unbiased rect layer for `loss = <g, W·x>`,
/// keyed by parameter name.
fn layer_grads(
    layer: &mut RectLinearSvd,
    x: &Mat,
    g: &Mat,
) -> std::collections::BTreeMap<String, Vec<f32>> {
    layer.zero_grads();
    let mut ctx = Ctx::empty();
    let _y = layer.forward(x, &mut ctx);
    let _dx = layer.backward(&ctx, g);
    collect_grads(layer).into_iter().collect()
}

/// Finite-difference gradients through the *inference* path (`apply`),
/// so analytic backward and forward-only code are cross-checked too.
fn gradcheck_rect(layer: &mut RectLinearSvd, rng: &mut Rng) {
    let (n, m) = (layer.p.rows, layer.p.cols);
    let x = Mat::randn(m, 3, rng);
    let g = Mat::randn(n, 3, rng);
    let k = layer.k;
    let got = layer_grads(layer, &x, &g);
    let p = layer.p.clone();
    let loss = |p2: &RectSvdParam, x2: &Mat| -> f64 {
        let y = p2.apply(x2, k);
        y.data().iter().zip(g.data()).map(|(&a, &b)| a as f64 * b as f64).sum()
    };

    let fd_u = oracle::finite_diff_grad(p.u.v.data(), 1e-3, |vals| {
        let mut p2 = p.clone();
        p2.u = HouseholderVectors::new(Mat::from_vec(n, n, vals.to_vec()));
        loss(&p2, &x)
    });
    assert_close(&got["u"], &fd_u, 1e-2, 8e-2).unwrap();

    let fd_v = oracle::finite_diff_grad(p.v.v.data(), 1e-3, |vals| {
        let mut p2 = p.clone();
        p2.v = HouseholderVectors::new(Mat::from_vec(m, m, vals.to_vec()));
        p2.refresh();
        loss(&p2, &x)
    });
    assert_close(&got["v"], &fd_v, 1e-2, 8e-2).unwrap();

    let fd_s = oracle::finite_diff_grad(&p.sigma, 1e-3, |vals| {
        let mut p2 = p.clone();
        p2.sigma = vals.to_vec();
        loss(&p2, &x)
    });
    assert_close(&got["sigma"], &fd_s, 1e-2, 5e-2).unwrap();
}

#[test]
fn rect_gradcheck_tall() {
    let mut rng = Rng::new(0xA1);
    let mut layer = RectLinearSvd::new_unbiased(9, 4, &mut rng);
    gradcheck_rect(&mut layer, &mut rng);
}

#[test]
fn rect_gradcheck_wide() {
    let mut rng = Rng::new(0xA2);
    let mut layer = RectLinearSvd::new_unbiased(4, 9, &mut rng);
    gradcheck_rect(&mut layer, &mut rng);
}

#[test]
fn rect_gradcheck_rank_truncated() {
    // truncate_rank zeroes part of the spectrum; gradients must still
    // match finite differences (σ = 0 is a regular point of the loss).
    let mut rng = Rng::new(0xA3);
    let mut layer = RectLinearSvd::new_unbiased(7, 6, &mut rng);
    for (i, s) in layer.p.sigma.iter_mut().enumerate() {
        *s = 0.4 + 0.3 * i as f32;
    }
    layer.p.truncate_rank(3);
    assert_eq!(layer.p.rank(), 3);
    gradcheck_rect(&mut layer, &mut rng);
}

#[test]
fn rect_gradcheck_through_sequential() {
    // The acceptance-criteria check: finite differences through a whole
    // Sequential (rect → tanh → rect) against the trait backward.
    let mut rng = Rng::new(0xA4);
    let model = Sequential::new()
        .push(RectLinearSvd::new_unbiased(6, 3, &mut rng))
        .push(Activation::Tanh)
        .push(RectLinearSvd::new_unbiased(2, 6, &mut rng));
    let x = Mat::randn(3, 4, &mut rng);
    let g = Mat::randn(2, 4, &mut rng);
    let (_y, ctxs) = model.forward(&x);
    let dx = model.backward(&ctxs, &g);
    let fd_x = oracle::finite_diff_grad(x.data(), 1e-3, |vals| {
        let x2 = Mat::from_vec(3, 4, vals.to_vec());
        let (y, _) = model.forward(&x2);
        y.data().iter().zip(g.data()).map(|(&a, &b)| a as f64 * b as f64).sum()
    });
    assert_close(dx.data(), &fd_x, 1e-2, 8e-2).unwrap();
}

#[test]
fn apply_pinv_roundtrip_at_ragged_k() {
    // Block sizes that do not divide either dimension: W⁺(W·x) = x for
    // tall full-column-rank W, and W(W⁺·y) = y for wide full-row-rank W.
    let mut rng = Rng::new(0xA5);
    for k in [1usize, 3, 5, 7] {
        let mut tall = RectSvdParam::random(17, 5, &mut rng);
        for (i, s) in tall.sigma.iter_mut().enumerate() {
            *s = 0.8 + 0.1 * i as f32;
        }
        let x = Mat::randn(5, 4, &mut rng);
        let back = tall.apply_pinv(&tall.apply(&x, k), k);
        assert!(back.max_abs_diff(&x) < 1e-3, "tall k={k}: diff {}", back.max_abs_diff(&x));

        let mut wide = RectSvdParam::random(5, 17, &mut rng);
        for (i, s) in wide.sigma.iter_mut().enumerate() {
            *s = 0.8 + 0.1 * i as f32;
        }
        let y = Mat::randn(5, 4, &mut rng);
        let fwd = wide.apply(&wide.apply_pinv(&y, k), k);
        assert!(fwd.max_abs_diff(&y) < 1e-3, "wide k={k}: diff {}", fwd.max_abs_diff(&y));
    }
}

#[test]
fn training_is_block_size_invariant_for_rect() {
    // k is a pure performance knob on the rectangular path too.
    let run = |k: usize| {
        let mut rng = Rng::new(0xA6);
        let mut layer = RectLinearSvd::new_unbiased(10, 6, &mut rng);
        layer.k = k;
        let mut opt = Sgd::new(0.05, 0.0);
        let x = Mat::randn(6, 5, &mut rng);
        let g = Mat::randn(10, 5, &mut rng);
        for _ in 0..6 {
            layer.zero_grads();
            let mut ctx = Ctx::empty();
            let _y = layer.forward(&x, &mut ctx);
            let _dx = layer.backward(&ctx, &g);
            opt.step(&mut layer);
            layer.post_update();
        }
        (layer.p.u.v.clone(), layer.p.sigma.clone())
    };
    let (ua, sa) = run(2);
    let (ub, sb) = run(9);
    assert_close(ua.data(), ub.data(), 1e-3, 1e-3).unwrap();
    assert_close(&sa, &sb, 1e-3, 1e-3).unwrap();
}

#[test]
fn optimizer_state_keys_survive_across_sweeps() {
    // Adam's per-parameter moments are keyed, not slot-indexed: the key
    // sequence a model exposes must be identical on every sweep, so the
    // optimizer state stays attached to the same tensors for the whole
    // run.
    let mut rng = Rng::new(0xA7);
    let build = |rng: &mut Rng| {
        Sequential::new()
            .push(RectLinearSvd::new(4, 3, rng))
            .push(Activation::Tanh)
            .push(RectLinearSvd::new(2, 4, rng))
    };
    let mut m1 = build(&mut rng);
    let keys = |m: &mut Sequential| -> Vec<String> {
        let mut ks = Vec::new();
        m.visit(&mut |pv| ks.push(pv.key.clone()));
        ks
    };
    let k_before = keys(&mut m1);
    let (x, y) = fasth::nn::tasks::linear_teacher(2, 3, 16, 0.0, &mut rng);
    let mut opt = Adam::new(0.01);
    for _ in 0..5 {
        m1.train_step(&x, |pred| mse(pred, &y), &mut opt);
    }
    assert_eq!(keys(&mut m1), k_before, "keys drifted across training sweeps");
    assert_eq!(opt.timestep(), 5);
}

#[test]
fn adam_timestep_advances_once_per_sweep() {
    // Two models sharing one optimizer: each step() call advances t once,
    // regardless of how many parameters the sweep visits.
    let mut rng = Rng::new(0xA8);
    let mut a = RectLinearSvd::new(3, 2, &mut rng);
    let mut opt = Adam::new(0.01);
    for _ in 0..3 {
        a.zero_grads();
        let mut ctx = Ctx::empty();
        let x = Mat::randn(2, 2, &mut rng);
        let g = Mat::randn(3, 2, &mut rng);
        let _y = a.forward(&x, &mut ctx);
        let _dx = a.backward(&ctx, &g);
        opt.step(&mut a);
        a.post_update();
    }
    assert_eq!(opt.timestep(), 3);
}
