//! End-to-end training smoke tests (fast versions of the examples):
//! losses must decrease and the reparameterization invariants must hold
//! throughout training.

use fasth::nn::loss::accuracy;
use fasth::nn::tasks::{copy_memory, spirals};
use fasth::nn::{softmax_cross_entropy, Activation, Dense, LinearSvd, SvdRnn};
use fasth::util::Rng;

#[test]
fn rnn_copy_memory_learns() {
    let mut rng = Rng::new(0x51);
    let mut rnn = SvdRnn::new(6, 48, 6, &mut rng);
    let mut first = None;
    let mut last = 0.0;
    for _ in 0..60 {
        let data = copy_memory(4, 2, 6, 32, &mut rng);
        let (loss, grads, _acc) = rnn.step_bptt(&data.inputs, &data.targets, data.scored_steps);
        rnn.sgd_step(&grads, 0.7);
        first.get_or_insert(loss);
        last = loss;
    }
    let first = first.unwrap();
    assert!(last < 0.8 * first, "RNN loss {first:.4} → {last:.4} (no learning)");
    // Spectrum stayed clipped the whole run.
    for &s in &rnn.w_rec.sigma {
        assert!((1.0 - rnn.eps..=1.0 + rnn.eps).contains(&s));
    }
    // Recurrent factors remain orthogonal after 60 updates.
    let u = rnn.w_rec.u.materialize();
    let utu = fasth::linalg::gemm::matmul_tn(&u, &u);
    assert!(utu.defect_from_identity() < 1e-3, "defect {}", utu.defect_from_identity());
}

#[test]
fn spiral_mlp_reaches_decent_accuracy() {
    let mut rng = Rng::new(0x52);
    let d = 24;
    let (x, y) = spirals(64, 0.05, &mut rng);
    let mut input = Dense::new(d, 2, &mut rng);
    let mut hidden = LinearSvd::new(d, &mut rng);
    let mut output = Dense::new(3, d, &mut rng);
    let act = Activation::Tanh;
    let mut acc = 0.0;
    for _ in 0..300 {
        let (h0, c0) = input.forward(&x);
        let a0 = act.forward(&h0);
        let (h1, c1) = hidden.forward(&a0);
        let a1 = act.forward(&h1);
        let (logits, c2) = output.forward(&a1);
        let (_loss, dlogits) = softmax_cross_entropy(&logits, &y);
        let (da1, dw2, db2) = output.backward(&c2, &dlogits);
        let dh1 = act.backward(&a1, &da1);
        let (da0, svd_grads, db1) = hidden.backward(&c1, &dh1);
        let dh0 = act.backward(&a0, &da0);
        let (_dx, dw0, db0) = input.backward(&c0, &dh0);
        output.sgd_step(&dw2, &db2, 0.5);
        hidden.sgd_step(&svd_grads, &db1, 0.5);
        hidden.clip_sigma(0.25);
        input.sgd_step(&dw0, &db0, 0.5);
        acc = accuracy(&logits, &y);
    }
    assert!(acc > 0.75, "spiral accuracy only {acc}");
    // The trained layer's condition number is bounded by the clip.
    let (lo, hi) = hidden
        .p
        .sigma
        .iter()
        .fold((f32::INFINITY, 0.0f32), |(lo, hi), &s| (lo.min(s), hi.max(s)));
    assert!(hi / lo <= 1.25 / 0.75 + 0.01);
}

#[test]
fn training_trajectory_engine_invariant() {
    // Training with FastH(k=4) equals training with FastH(k=16): the block
    // size is a pure performance knob, not a modeling choice.
    let run = |k: usize| {
        let mut rng = Rng::new(0x53);
        let mut layer = LinearSvd::new(12, &mut rng);
        layer.k = k;
        let x = fasth::linalg::Mat::randn(12, 6, &mut rng);
        let g = fasth::linalg::Mat::randn(12, 6, &mut rng);
        for _ in 0..8 {
            let (_y, c) = layer.forward(&x);
            let (_dx, grads, db) = layer.backward(&c, &g);
            layer.sgd_step(&grads, &db, 0.05);
        }
        layer.p.u.v.clone()
    };
    let a = run(4);
    let b = run(16);
    fasth::util::prop::assert_close(a.data(), b.data(), 1e-3, 1e-3).unwrap();
}
