//! End-to-end training smoke tests (fast versions of the examples):
//! losses must decrease and the reparameterization invariants must hold
//! throughout training — all through the unified `Layer`/`Params` traits
//! and a single optimizer sweep per step (no per-layer `sgd_step`s, no
//! manual slots).

use fasth::nn::loss::accuracy;
use fasth::nn::tasks::{copy_memory, linear_teacher, spirals};
use fasth::nn::{
    mse, softmax_cross_entropy, Activation, Adam, Ctx, Dense, Layer, LinearSvd, Optimizer,
    Params, RectLinearSvd, Sequential, Sgd, SigmaClip, SvdRnn,
};
use fasth::util::Rng;

#[test]
fn rnn_copy_memory_learns() {
    let mut rng = Rng::new(0x51);
    let mut rnn = SvdRnn::new(6, 48, 6, &mut rng);
    let mut opt = Sgd::new(0.7, 0.0);
    let mut first = None;
    let mut last = 0.0;
    for _ in 0..60 {
        let data = copy_memory(4, 2, 6, 32, &mut rng);
        let (loss, _acc) =
            rnn.train_step(&data.inputs, &data.targets, data.scored_steps, &mut opt);
        first.get_or_insert(loss);
        last = loss;
    }
    let first = first.unwrap();
    assert!(last < 0.8 * first, "RNN loss {first:.4} → {last:.4} (no learning)");
    // Spectrum stayed clipped the whole run.
    for &s in &rnn.w_rec.p.sigma {
        assert!((1.0 - rnn.eps()..=1.0 + rnn.eps()).contains(&s));
    }
    // Recurrent factors remain orthogonal after 60 updates.
    let u = rnn.w_rec.p.u.materialize();
    let utu = fasth::linalg::gemm::matmul_tn(&u, &u);
    assert!(utu.defect_from_identity() < 1e-3, "defect {}", utu.defect_from_identity());
}

#[test]
fn spiral_mlp_reaches_decent_accuracy() {
    let mut rng = Rng::new(0x52);
    let d = 24;
    let (x, y) = spirals(64, 0.05, &mut rng);
    let mut model = Sequential::new()
        .push(Dense::new(d, 2, &mut rng))
        .push(Activation::Tanh)
        .push(LinearSvd::new(d, &mut rng).with_clip(SigmaClip::Band(0.25)))
        .push(Activation::Tanh)
        .push(Dense::new(3, d, &mut rng));
    let mut opt = Sgd::new(0.5, 0.0);
    let mut acc = 0.0;
    for _ in 0..300 {
        let (_loss, logits) =
            model.train_step(&x, |l| softmax_cross_entropy(l, &y), &mut opt);
        acc = accuracy(&logits, &y);
    }
    assert!(acc > 0.75, "spiral accuracy only {acc}");
    // The trained layer's condition number is bounded by the clip; read
    // the spectrum back through the visit sweep.
    let mut sigma = Vec::new();
    model.visit(&mut |pv| {
        if pv.key == "2.sigma" {
            sigma = pv.param.to_vec();
        }
    });
    assert!(!sigma.is_empty());
    let (lo, hi) =
        sigma.iter().fold((f32::INFINITY, 0.0f32), |(lo, hi), &s| (lo.min(s), hi.max(s)));
    assert!(hi / lo <= 1.25 / 0.75 + 0.01);
}

#[test]
fn rect_linear_svd_trains_end_to_end_with_adam() {
    // The acceptance workload: a *non-square* SVD layer (12 → 5 via
    // U·Σ·Vᵀ with U ∈ ℝ^{5×5}, V ∈ ℝ^{12×12}) regressing a rectangular
    // teacher through Sequential + Adam + MSE.
    let mut rng = Rng::new(0x54);
    let (out_dim, in_dim) = (5usize, 12usize);
    let (x, y) = linear_teacher(out_dim, in_dim, 64, 0.01, &mut rng);
    let mut model = Sequential::new().push(RectLinearSvd::new(out_dim, in_dim, &mut rng));
    let mut opt = Adam::new(0.02);
    let mut first = None;
    let mut last = 0.0;
    for _ in 0..150 {
        let (loss, _pred) = model.train_step(&x, |pred| mse(pred, &y), &mut opt);
        first.get_or_insert(loss);
        last = loss;
    }
    let first = first.unwrap();
    assert!(
        last < 0.2 * first,
        "rect layer did not learn the teacher: {first:.5} → {last:.5}"
    );
    // The factors are still exactly orthogonal after 150 Adam sweeps —
    // the invariant that makes the SVD view trustworthy.
    let layer_sigma = {
        let mut s = Vec::new();
        model.visit(&mut |pv| {
            if pv.key == "0.sigma" {
                s = pv.param.to_vec();
            }
        });
        s
    };
    assert_eq!(layer_sigma.len(), out_dim.min(in_dim));
    assert!(layer_sigma.iter().all(|v| v.is_finite()));
}

#[test]
fn deep_rect_mlp_with_adam_classifies_spirals() {
    // Rectangular SVD layers as *input and output* projections around a
    // square LinearSvd — no Dense anywhere; the whole stack is SVD-
    // parameterized and trained by one Adam sweep per step.
    let mut rng = Rng::new(0x55);
    let d = 16;
    let (x, y) = spirals(48, 0.05, &mut rng);
    let mut model = Sequential::new()
        .push(RectLinearSvd::new(d, 2, &mut rng))
        .push(Activation::Tanh)
        .push(LinearSvd::new(d, &mut rng).with_clip(SigmaClip::Band(0.5)))
        .push(Activation::Tanh)
        .push(RectLinearSvd::new(3, d, &mut rng));
    let mut opt = Adam::new(0.02);
    let mut acc = 0.0;
    for _ in 0..350 {
        let (_loss, logits) =
            model.train_step(&x, |l| softmax_cross_entropy(l, &y), &mut opt);
        acc = accuracy(&logits, &y);
    }
    assert!(acc > 0.65, "all-SVD spiral accuracy only {acc}");
}

#[test]
fn training_trajectory_engine_invariant() {
    // Training with FastH(k=4) equals training with FastH(k=16): the block
    // size is a pure performance knob, not a modeling choice. A single
    // layer is itself a Params — the optimizer sweeps it directly.
    let run = |k: usize| {
        let mut rng = Rng::new(0x53);
        let mut layer = LinearSvd::new(12, &mut rng);
        layer.k = k;
        let mut opt = Sgd::new(0.05, 0.0);
        let x = fasth::linalg::Mat::randn(12, 6, &mut rng);
        let g = fasth::linalg::Mat::randn(12, 6, &mut rng);
        for _ in 0..8 {
            layer.zero_grads();
            let mut ctx = Ctx::empty();
            let _y = layer.forward(&x, &mut ctx);
            let _dx = layer.backward(&ctx, &g);
            opt.step(&mut layer);
            layer.post_update();
        }
        layer.p.u.v.clone()
    };
    let a = run(4);
    let b = run(16);
    fasth::util::prop::assert_close(a.data(), b.data(), 1e-3, 1e-3).unwrap();
}
