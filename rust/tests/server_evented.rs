//! Evented-core end-to-end properties: pipelining backpressure (flood a
//! connection far past `max_pipeline` — nothing lost, nothing
//! reordered), slow-reader throttling (a client that stops reading gets
//! paused, not dropped), the v1 version handshake (accept, reject,
//! implicit-v1), and connection-churn conservation (every accepted
//! connection is torn down and the gauges return to zero).

use fasth::coordinator::{
    Call, Client, ErrorCode, ExecEngine, FaultPlan, ModelRegistry, OpKind, Request, Response,
    Server, ServerConfig,
};
use fasth::util::Rng;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn request_line(id: u64, model: &str, column: Vec<f32>) -> String {
    Request {
        id,
        model: model.into(),
        op: OpKind::Apply,
        column,
        ttl_ms: None,
        rank: None,
        timing: false,
        sampled: false,
    }
        .to_json()
}

/// Flood one raw connection with far more requests than `max_pipeline`
/// allows in flight. The reactor must pause reading (backpressure is
/// observable via `conn_pauses`) instead of queueing without bound, and
/// the single-shard single-worker pipeline must deliver every response
/// in request order.
#[test]
fn pipelining_backpressure_no_loss_no_reorder() {
    let registry = Arc::new(ModelRegistry::new());
    registry.create("m8", 8, ExecEngine::Native { k: 4 }, 0xBACC);
    let config = ServerConfig::builder()
        .shards(1)
        .workers(1)
        .max_batch(8)
        .max_wait(Duration::from_millis(1))
        .max_queue_depth(1000)
        .max_pipeline(4)
        .build()
        .unwrap();
    let server = Server::start(config, registry).unwrap();

    let n = 100u64;
    let stream = TcpStream::connect(server.local_addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = BufWriter::new(stream);
    let mut rng = Rng::new(0xF100D);
    for id in 1..=n {
        let col: Vec<f32> = (0..8).map(|_| rng.normal_f32()).collect();
        writeln!(writer, "{}", request_line(id, "m8", col)).unwrap();
    }
    writer.flush().unwrap();

    let mut line = String::new();
    for expect in 1..=n {
        line.clear();
        assert!(reader.read_line(&mut line).unwrap() > 0, "EOF before response {expect}");
        let resp = Response::from_json(line.trim()).unwrap();
        assert!(resp.ok, "response {expect} failed: {:?}", resp.error);
        assert_eq!(resp.id, expect, "responses reordered");
    }
    assert!(
        server.metrics.conn_pauses.load(Ordering::Relaxed) >= 1,
        "flooding {n} requests past max_pipeline=4 never paused the connection"
    );
    server.stop();
}

/// A client that submits a large volume of traffic and then stops
/// reading must be throttled — responses pile up to `write_buf_cap`,
/// the reactor pauses the connection — and *not* disconnected: once the
/// client starts draining, every response arrives in order and the
/// connection stays usable.
#[test]
fn slow_reader_is_throttled_not_dropped() {
    let d = 128usize;
    let n = 400u64;
    let registry = Arc::new(ModelRegistry::new());
    registry.create("m128", d, ExecEngine::Native { k: 16 }, 0x510);
    let config = ServerConfig::builder()
        .shards(1)
        .workers(1)
        .max_batch(32)
        .max_wait(Duration::from_millis(1))
        .max_queue_depth(10_000)
        // Huge pipeline cap: this test isolates the *write-side* cap.
        .max_pipeline(1_000_000)
        .write_buf_cap(8 * 1024)
        .sock_buf(4 * 1024)
        .build()
        .unwrap();
    let server = Server::start(config, registry).unwrap();

    let stream = TcpStream::connect(server.local_addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    // Writer thread: the reactor may pause reading while we are not
    // draining responses yet, so the flood must not share a thread with
    // the eventual reads.
    let writer_stream = stream.try_clone().unwrap();
    let writer = std::thread::spawn(move || {
        let mut w = BufWriter::new(writer_stream);
        let mut rng = Rng::new(0x51_0E);
        for id in 1..=n {
            let col: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
            writeln!(w, "{}", request_line(id, "m128", col)).unwrap();
        }
        w.flush().unwrap();
    });

    // Play the slow reader: give the server time to fill the socket and
    // hit the write cap. `SO_SNDBUF` is only a real knob on Linux, so
    // only there is the pause deterministic enough to assert.
    #[cfg(target_os = "linux")]
    {
        let t0 = Instant::now();
        while server.metrics.conn_pauses.load(Ordering::Relaxed) == 0 {
            assert!(
                t0.elapsed() < Duration::from_secs(10),
                "slow reader never tripped the write-cap pause"
            );
            std::thread::sleep(Duration::from_millis(5));
        }
    }
    #[cfg(not(target_os = "linux"))]
    std::thread::sleep(Duration::from_millis(200));

    // Drain: every response present, in order, none dropped.
    let mut line = String::new();
    for expect in 1..=n {
        line.clear();
        assert!(reader.read_line(&mut line).unwrap() > 0, "EOF before response {expect}");
        let resp = Response::from_json(line.trim()).unwrap();
        assert!(resp.ok, "response {expect} failed: {:?}", resp.error);
        assert_eq!(resp.id, expect, "responses reordered");
    }
    writer.join().unwrap();

    // The connection survived the throttling and still serves.
    let mut w = BufWriter::new(stream);
    writeln!(w, "{}", request_line(n + 1, "m128", vec![0.5; d])).unwrap();
    w.flush().unwrap();
    line.clear();
    assert!(reader.read_line(&mut line).unwrap() > 0, "connection dead after throttle");
    let resp = Response::from_json(line.trim()).unwrap();
    assert!(resp.ok);
    assert_eq!(resp.id, n + 1);
    server.stop();
}

/// The v1 handshake: a matching hello is confirmed, a future protocol
/// version gets a structured error envelope and a close, and a client
/// that never says hello is served as implicit v1.
#[test]
fn hello_handshake_and_version_rejection() {
    let registry = Arc::new(ModelRegistry::new());
    registry.create("m8", 8, ExecEngine::Native { k: 4 }, 0x4E);
    let config = ServerConfig::builder().shards(1).workers(1).build().unwrap();
    let server = Server::start(config, registry).unwrap();

    // Typed client: handshake on connect, version recorded.
    let mut client = Client::connect(&server.local_addr).unwrap();
    assert_eq!(client.server_proto(), Some(1));
    assert!(client.call(Call::apply("m8", vec![0.5; 8])).unwrap().ok);

    // A client from the future: structured rejection, then close.
    let stream = TcpStream::connect(server.local_addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut w = BufWriter::new(stream);
    writeln!(w, "{{\"cmd\":\"hello\",\"proto\":99}}").unwrap();
    w.flush().unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let j = fasth::util::json::Json::parse(line.trim()).unwrap();
    assert_eq!(j.get("ok").as_bool(), Some(false), "{line}");
    assert_eq!(j.get("proto").as_usize(), Some(1), "{line}");
    let err = j.get("error").as_str().unwrap().to_string();
    assert!(err.contains("unsupported proto 99"), "{err}");
    line.clear();
    assert_eq!(reader.read_line(&mut line).unwrap(), 0, "server must close after rejection");

    // No hello at all: implicit v1, requests served as before.
    let stream = TcpStream::connect(server.local_addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut w = BufWriter::new(stream);
    writeln!(w, "{}", request_line(7, "m8", vec![0.25; 8])).unwrap();
    w.flush().unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let resp = Response::from_json(line.trim()).unwrap();
    assert!(resp.ok, "{:?}", resp.error);
    assert_eq!(resp.id, 7);
    server.stop();
}

/// Overload across racing reactors: three reactors flooding one shard
/// far past `max_queue_depth` (service slowed by injected latency so
/// the queue actually backs up). The depth check and enqueue are one
/// atomic step inside the batcher, so a sampler hammering the depth
/// gauge must never observe the cap exceeded; every request gets
/// exactly one response; and rejections carry the structured
/// `code=overloaded, retryable=true` envelope.
#[test]
fn overload_rejections_never_overshoot_queue_cap() {
    let cap = 32usize;
    let registry = Arc::new(ModelRegistry::new());
    registry.create("m8", 8, ExecEngine::Native { k: 4 }, 0x0E8);
    let config = ServerConfig::builder()
        .shards(1)
        .workers(1)
        .reactors(3)
        .max_batch(8)
        .max_wait(Duration::from_millis(1))
        .max_queue_depth(cap)
        .faults(FaultPlan::new().delay_every(1, Duration::from_millis(15)))
        .build()
        .unwrap();
    let server = Server::start(config, registry).unwrap();
    let addr = server.local_addr;

    // Sampler: the cap invariant must hold at every observable instant,
    // not just at quiescence.
    let shards = server.shards.clone();
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let sampler = {
        let stop = stop.clone();
        std::thread::spawn(move || {
            let mut max_depth = 0usize;
            while !stop.load(Ordering::Relaxed) {
                let depth: usize = shards.depths().iter().sum();
                max_depth = max_depth.max(depth);
                std::thread::yield_now();
            }
            max_depth
        })
    };

    let floods = 3usize;
    let per_conn = 200u64;
    let flooders: Vec<_> = (0..floods)
        .map(|_| {
            std::thread::spawn(move || {
                let stream = TcpStream::connect(addr).unwrap();
                stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let mut writer = BufWriter::new(stream);
                for id in 1..=per_conn {
                    writeln!(writer, "{}", request_line(id, "m8", vec![0.5; 8])).unwrap();
                }
                writer.flush().unwrap();
                // Exactly one response per id. Order is NOT asserted:
                // rejections are answered inline by the reactor while
                // served responses come back from the worker, so the
                // two streams interleave.
                let mut seen = std::collections::BTreeSet::new();
                let (mut served, mut rejected) = (0u64, 0u64);
                let mut line = String::new();
                for nth in 1..=per_conn {
                    line.clear();
                    assert!(reader.read_line(&mut line).unwrap() > 0, "EOF before response {nth}");
                    let resp = Response::from_json(line.trim()).unwrap();
                    assert!(
                        (1..=per_conn).contains(&resp.id) && seen.insert(resp.id),
                        "duplicate or alien response id {}",
                        resp.id
                    );
                    if resp.ok {
                        served += 1;
                    } else {
                        assert_eq!(
                            resp.code,
                            Some(ErrorCode::Overloaded),
                            "unexpected rejection: {:?}",
                            resp.error
                        );
                        assert!(resp.retryable, "overloaded must be marked retryable");
                        rejected += 1;
                    }
                }
                (served, rejected)
            })
        })
        .collect();
    let (mut served, mut rejected) = (0u64, 0u64);
    for f in flooders {
        let (s, r) = f.join().unwrap();
        served += s;
        rejected += r;
    }
    stop.store(true, Ordering::Relaxed);
    let max_depth = sampler.join().unwrap();

    assert_eq!(served + rejected, floods as u64 * per_conn, "responses lost or duplicated");
    assert!(served >= 1, "nothing served under flood");
    assert!(rejected >= 1, "flood of {} past cap {cap} never rejected", floods as u64 * per_conn);
    assert!(max_depth <= cap, "queue cap overshot: observed depth {max_depth} > cap {cap}");
    server.stop();
}

/// Hundreds of short-lived connections across threads: every call
/// succeeds, the total-connections counter saw them all, and once the
/// dust settles the open-connections gauge returns to zero (no leaked
/// routes, no leaked fds).
#[test]
fn connection_churn_conservation() {
    let registry = Arc::new(ModelRegistry::new());
    registry.create("m8", 8, ExecEngine::Native { k: 4 }, 0xC0);
    let config = ServerConfig::builder().shards(2).workers(2).reactors(2).build().unwrap();
    let server = Server::start(config, registry).unwrap();
    let addr = server.local_addr;

    let threads = 8usize;
    let per_thread = 20usize;
    let handles: Vec<_> = (0..threads)
        .map(|t| {
            std::thread::spawn(move || {
                let mut rng = Rng::new(0xC482 + t as u64);
                for _ in 0..per_thread {
                    let mut client = Client::connect(&addr).unwrap();
                    let col: Vec<f32> = (0..8).map(|_| rng.normal_f32()).collect();
                    let r = client.call(Call::apply("m8", col)).unwrap();
                    assert!(r.ok, "{:?}", r.error);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }

    let total = server.metrics.connections_total.load(Ordering::Relaxed);
    assert!(
        total >= (threads * per_thread) as u64,
        "connections_total {total} < {}",
        threads * per_thread
    );
    // Teardown is asynchronous (the owning reactor sweeps closed
    // connections on its next tick); poll briefly for conservation.
    let t0 = Instant::now();
    loop {
        let open = server.metrics.connections_open.load(Ordering::Relaxed);
        if open == 0 {
            break;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "{open} connections still open after churn (leaked routes?)"
        );
        std::thread::sleep(Duration::from_millis(10));
    }
    server.stop();
}
