//! Observability properties: span conservation under a pipelined
//! multi-connection burst, ring overwrite semantics, and Prometheus
//! cumulative-bucket monotonicity.
//!
//! The burst test re-derives its traffic mix (models, ops, burst sizes)
//! from `FASTH_PROP_SEED` — the nightly trace-sweep lane rotates that
//! seed so span conservation is checked on a fresh interleaving every
//! night. Replay a failure locally with:
//! `FASTH_PROP_SEED=<seed> cargo test -q --test trace_obs`

use fasth::coordinator::metrics::Metrics;
use fasth::coordinator::{Call, Client, ExecEngine, ModelRegistry, OpKind, Server, ServerConfig};
use fasth::obs::{self, Span, SpanRing, Stage};
use fasth::util::json::Json;
use fasth::util::Rng;
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

fn master_seed() -> u64 {
    // Same convention as util::prop: a fixed master seed keeps CI
    // deterministic; FASTH_PROP_SEED overrides for the nightly sweep.
    std::env::var("FASTH_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xFA57_0B50u64)
}

/// Span conservation: under a pipelined burst from several concurrent
/// connections against a trace-everything server, every `timing: true`
/// request must (a) echo a breakdown whose disjoint stage sum is bounded
/// by the server-observed total, and (b) leave exactly one complete
/// QueueWait → BatchForm → Exec → Writeback span chain in the rings,
/// keyed by its conn-tagged id — no request loses or duplicates a stage
/// regardless of how batches interleave.
#[test]
fn pipelined_burst_conserves_span_chains() {
    let master = master_seed();
    let registry = Arc::new(ModelRegistry::new());
    registry.create("tr_16", 16, ExecEngine::Native { k: 4 }, 91);
    registry.create("tr_24", 24, ExecEngine::Native { k: 4 }, 92);
    let config = ServerConfig::builder()
        .shards(2)
        .workers(2)
        .reactors(2)
        .max_batch(8)
        .max_wait(Duration::from_millis(1))
        .max_queue_depth(10_000)
        .trace_sample(1)
        .build()
        .unwrap();
    let server = Server::start(config, registry).unwrap();
    let addr = server.local_addr;

    const CONNS: usize = 3;
    const PER_CONN: usize = 40;
    let handles: Vec<_> = (0..CONNS)
        .map(|c| {
            std::thread::spawn(move || {
                let mut rng = Rng::new(master.wrapping_add(0xC0 + c as u64));
                let mut client = Client::connect(&addr).unwrap();
                let mut done = 0usize;
                while done < PER_CONN {
                    let burst = (1 + rng.below(8)).min(PER_CONN - done);
                    let (model, op, d) = match rng.below(4) {
                        0 => ("tr_16", OpKind::Apply, 16),
                        1 => ("tr_16", OpKind::Inverse, 16),
                        2 => ("tr_24", OpKind::Apply, 24),
                        _ => ("tr_24", OpKind::Inverse, 24),
                    };
                    let calls: Vec<Call> = (0..burst)
                        .map(|_| {
                            let col: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
                            Call::new(model, op, col).timing()
                        })
                        .collect();
                    for r in client.call_many(calls).unwrap() {
                        assert!(r.ok, "request failed: {:?}", r.error);
                        let t = r.timing.expect("timing: true must echo a breakdown");
                        assert!(
                            t.stage_sum_us() <= t.total_us,
                            "stage sum {} exceeds server total {}",
                            t.stage_sum_us(),
                            t.total_us
                        );
                    }
                    done += burst;
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }

    // Conservation over the in-process rings: group request-correlated
    // spans (client bits nonzero; conn-level reactor spans have them
    // zero) by id and demand one full worker chain per request sent.
    let spans = obs::recent_spans(usize::MAX);
    let mut per_id: HashMap<u64, [u32; Stage::ALL.len()]> = HashMap::new();
    for s in &spans {
        if s.id & 0xFFFF_FFFF != 0 {
            per_id.entry(s.id).or_insert([0; Stage::ALL.len()])[s.stage.index()] += 1;
        }
    }
    let chain = [Stage::QueueWait, Stage::BatchForm, Stage::Exec, Stage::Writeback];
    let complete = per_id
        .values()
        .filter(|counts| chain.iter().all(|st| counts[st.index()] == 1))
        .count();
    assert_eq!(
        complete,
        CONNS * PER_CONN,
        "every timing request must leave exactly one complete span chain \
         ({} ids seen, {} spans total)",
        per_id.len(),
        spans.len()
    );
    for (id, counts) in &per_id {
        for st in chain {
            assert_eq!(
                counts[st.index()],
                1,
                "request {id:#x}: stage {} recorded {} times",
                st.name(),
                counts[st.index()]
            );
        }
        assert_eq!(counts[Stage::Decode.index()], 1, "request {id:#x}: missing decode span");
    }

    // The trace admin command serves the same data over the wire.
    let mut admin = Client::connect(&addr).unwrap();
    let reply = admin.trace_json(65_536).unwrap();
    let j = Json::parse(&reply).unwrap();
    assert_eq!(j.get("sample_every").as_usize(), Some(1), "{reply}");
    let wire_spans = j.get("spans").as_arr().expect("spans array");
    assert!(j.get("count").as_usize().unwrap() >= CONNS * PER_CONN * chain.len());
    assert_eq!(wire_spans.len(), j.get("count").as_usize().unwrap());
    for s in wire_spans {
        let name = s.get("stage").as_str().expect("stage name");
        assert!(Stage::ALL.iter().any(|st| st.name() == name), "unknown stage '{name}'");
    }
    server.stop();
}

/// Ring overwrite semantics through the public API: a lapped ring stays
/// bounded at capacity, keeps exactly the most recent pushes oldest
/// first, and still counts every push ever made.
#[test]
fn ring_overwrite_keeps_most_recent_bounded() {
    let ring = SpanRing::new(32);
    for n in 0..100u64 {
        ring.push(Span { id: n, stage: Stage::Exec, start_us: n, dur_us: 1 });
    }
    assert_eq!(ring.capacity(), 32);
    assert_eq!(ring.len(), 32, "bounded: capacity never exceeded");
    assert_eq!(ring.pushed(), 100, "overwrites still count as pushed");
    let ids: Vec<u64> = ring.snapshot().iter().map(|s| s.id).collect();
    assert_eq!(ids, (68..100).collect::<Vec<u64>>(), "most recent survive, oldest first");
}

/// Every histogram family in the Prometheus exposition must be a valid
/// cumulative histogram: bucket counts non-decreasing as `le` grows,
/// closed by a `+Inf` bucket that equals the family's total count.
#[test]
fn prometheus_cumulative_buckets_are_monotonic() {
    let m = Metrics::new();
    let mut rng = Rng::new(master_seed() ^ 0x9E37);
    const N: usize = 500;
    for _ in 0..N {
        // Spread across the full bucket range, including the open tail.
        let us = rng.below(2_000_000) as u64;
        m.record_latency(us);
        m.record_latency_op(OpKind::Apply, us);
        m.record_queue_wait_op(OpKind::Apply, us / 3);
        m.record_exec_op(OpKind::Inverse, us / 2);
    }
    let text = m.to_prometheus(&[1, 2], &[3]);

    // Group bucket lines by everything left of the `le` label; within a
    // family the exposition emits buckets in increasing-`le` order.
    let mut last: HashMap<String, (u64, bool)> = HashMap::new();
    for line in text.lines() {
        let Some(pos) = line.find("le=\"") else { continue };
        let key = line[..pos].to_string();
        let le = line[pos + 4..].split('"').next().unwrap();
        let val: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
        let entry = last.entry(key.clone()).or_insert((0, false));
        assert!(!entry.1, "family {key}: bucket after +Inf");
        assert!(
            val >= entry.0,
            "family {key}: cumulative count decreased ({} -> {val}) at le={le}",
            entry.0
        );
        entry.0 = val;
        if le == "+Inf" {
            entry.1 = true;
        }
    }
    assert!(!last.is_empty(), "no histogram buckets in exposition:\n{text}");
    for (key, (_, saw_inf)) in &last {
        assert!(saw_inf, "family {key}: missing +Inf bucket");
    }
    // The aggregate family's +Inf bucket conserves the total count.
    let inf_line = format!("orthoserve_latency_aggregate_us_bucket{{le=\"+Inf\"}} {N}");
    assert!(text.contains(&inf_line), "aggregate +Inf != {N}:\n{text}");
}
