//! Integration suite for the experiments subsystem: the determinism
//! contract (same spec + seed ⇒ byte-identical metrics, wall-time
//! excluded), RunRecord serde round-trips with the schema-version guard,
//! the end-to-end artifact pipeline (runner → disk → report), and the
//! NaN gate.

use fasth::experiments::workloads::run_one;
use fasth::experiments::{
    builtin, builtin_all, report, Budget, ExperimentSpec, Family, RunRecord, Runner,
    SCHEMA_VERSION,
};
use fasth::util::json::Json;
use std::path::PathBuf;

/// Scale a builtin down to test size (1–2 epochs, 2 steps, 2 seeds).
fn tiny(name: &str) -> ExperimentSpec {
    let mut spec = builtin(name, Budget::Smoke).unwrap();
    spec.epochs = 2;
    spec.steps_per_epoch = 2;
    spec.seeds = vec![1, 2];
    spec
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("fasth_exp_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn same_spec_and_seed_is_byte_identical_modulo_wall_time() {
    // The ISSUE-level determinism contract, across every workload kind:
    // run the identical spec twice (threaded fan-out both times) and
    // compare each record's metric fingerprint byte-for-byte.
    for name in ["char_lm", "flow_d8", "spiral", "teacher"] {
        let spec = tiny(name);
        let runner = Runner { persist: false, ..Runner::default() };
        let a = runner.run_spec(&spec).unwrap();
        let b = runner.run_spec(&spec).unwrap();
        assert_eq!(a.len(), b.len());
        for (ra, rb) in a.iter().zip(&b) {
            assert_eq!(
                ra.fingerprint(),
                rb.fingerprint(),
                "{name}/{}/s{} not deterministic",
                ra.family,
                ra.seed
            );
            // Wall-time may differ run to run; the full JSON need not
            // match, the metrics subset must.
            assert!(ra.wall_secs >= 0.0 && rb.wall_secs >= 0.0);
        }
    }
}

#[test]
fn record_roundtrips_through_disk_with_schema_guard() {
    let spec = tiny("teacher");
    let rec = run_one(&spec, Family::RectSvdMlp, 5).unwrap();
    let dir = tmp_dir("roundtrip");
    let path = rec.save(&dir).unwrap();

    // Byte-level round-trip: load → same fingerprint and same full JSON.
    let loaded = RunRecord::load(&path).unwrap();
    assert_eq!(rec.fingerprint(), loaded.fingerprint());
    assert_eq!(rec.to_json().to_string(), loaded.to_json().to_string());
    assert_eq!(loaded.schema_version, SCHEMA_VERSION);

    // Schema-version guard: a bumped version must be rejected.
    let text = std::fs::read_to_string(&path).unwrap();
    let mut j = Json::parse(&text).unwrap();
    if let Json::Obj(o) = &mut j {
        o.insert("schema_version".into(), Json::num(SCHEMA_VERSION as f64 + 1.0));
    }
    std::fs::write(&path, j.to_string()).unwrap();
    let err = RunRecord::load(&path).unwrap_err();
    assert!(err.contains("schema_version"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn smoke_suite_covers_workloads_families_and_reports() {
    // A miniature `repro experiment all --budget smoke`: every builtin of
    // the smoke tier at test scale, through the threaded runner, into
    // artifacts, aggregated into the Table-2 report.
    let dir = tmp_dir("suite");
    let runner = Runner::with_out_dir(&dir);
    let specs: Vec<ExperimentSpec> =
        builtin_all(Budget::Smoke).iter().map(|s| tiny(&s.name)).collect();
    let records = runner.run_all(&specs).unwrap();

    // The acceptance floor: ≥ 3 workloads × ≥ 2 families, ≥ 2 seeds.
    let workloads: std::collections::BTreeSet<&str> =
        records.iter().map(|r| r.workload.as_str()).collect();
    let families: std::collections::BTreeSet<&str> =
        records.iter().map(|r| r.family.as_str()).collect();
    assert!(workloads.len() >= 3, "{workloads:?}");
    assert!(families.len() >= 2, "{families:?}");
    assert!(records.iter().all(|r| r.all_finite()), "NaN/divergence in smoke suite");

    // Artifacts landed and reload to the same fingerprints.
    let loaded = RunRecord::load_dir(&dir).unwrap();
    assert_eq!(loaded.len(), records.len());

    // Report: every (workload, family) cell has both seeds aggregated,
    // and the markdown table mentions every family column.
    let cells = report::aggregate(&loaded);
    assert!(cells.iter().all(|c| c.n_seeds == 2), "mean ± std needs both seeds");
    let md = report::markdown(&cells);
    for f in &families {
        assert!(md.contains(f), "family '{f}' missing from:\n{md}");
    }
    assert!(md.contains('±'));
    let j = report::to_json(&cells, "smoke", loaded.len());
    assert_eq!(j.get("workloads").as_usize(), Some(workloads.len()));
    assert_eq!(j.get("families").as_usize(), Some(families.len()));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn seed_offset_changes_metrics_but_not_structure() {
    // The nightly lane shifts seeds; shifted runs must stay finite and
    // produce different metric streams.
    let spec = tiny("spiral");
    let mut shifted = spec.clone();
    for s in &mut shifted.seeds {
        *s += 1000;
    }
    let base = run_one(&spec, Family::SvdMlp, spec.seeds[0]).unwrap();
    let moved = run_one(&shifted, Family::SvdMlp, shifted.seeds[0]).unwrap();
    assert!(base.all_finite() && moved.all_finite());
    assert_ne!(base.fingerprint(), moved.fingerprint());
    assert_eq!(base.workload, moved.workload);
    assert_eq!(base.epochs.len(), moved.epochs.len());
}

#[test]
fn sigma_spectrum_is_sampled_per_epoch_for_svd_families() {
    let spec = tiny("char_lm");
    let svd = run_one(&spec, Family::SvdRnn, 1).unwrap();
    for e in &svd.epochs {
        let s = e.sigma.expect("SVD-RNN must sample σ each epoch");
        // Spectral clip keeps σ in [1−ε, 1+ε].
        assert!(s.min >= 0.94 && s.max <= 1.06, "σ stats out of band: {s:?}");
    }
    let dense = run_one(&spec, Family::DenseRnn, 1).unwrap();
    assert!(dense.epochs.iter().all(|e| e.sigma.is_none()));
}
