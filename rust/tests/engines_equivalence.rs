//! Cross-engine integration: the paper's "no loss of quality" claim (§5)
//! at sizes larger than the unit tests use — sequential, parallel, and
//! FastH (several k) must agree on outputs and gradients, and the
//! algebraic invariants of the orthogonal parameterization must hold end
//! to end.

use fasth::householder::{Engine, HouseholderVectors};
use fasth::linalg::Mat;
use fasth::util::prop::{assert_close, check};
use fasth::util::Rng;

#[test]
fn all_engines_agree_at_realistic_size() {
    let mut rng = Rng::new(0xE1);
    let (d, m) = (192, 32);
    let hv = HouseholderVectors::random_full(d, &mut rng);
    let x = Mat::randn(d, m, &mut rng);
    let g = Mat::randn(d, m, &mut rng);

    let (a_seq, dx_seq, dv_seq) = Engine::Sequential.step(&hv, &x, &g);
    for engine in [
        Engine::Parallel,
        Engine::FastH { k: 8 },
        Engine::FastH { k: 14 }, // ragged: 14 ∤ 192
        Engine::FastH { k: 32 },
        Engine::FastH { k: 192 },
    ] {
        let (a, dx, dv) = engine.step(&hv, &x, &g);
        assert_close(a.data(), a_seq.data(), 2e-3, 2e-3)
            .unwrap_or_else(|e| panic!("{} fwd: {e}", engine.name()));
        assert_close(dx.data(), dx_seq.data(), 2e-3, 2e-3)
            .unwrap_or_else(|e| panic!("{} dx: {e}", engine.name()));
        assert_close(dv.data(), dv_seq.data(), 5e-3, 5e-3)
            .unwrap_or_else(|e| panic!("{} dv: {e}", engine.name()));
    }
}

#[test]
fn property_orthogonality_invariants() {
    check("orthogonality_invariants", 12, |rng| {
        let d = 8 + rng.below(80);
        let m = 1 + rng.below(16);
        let k = 1 + rng.below(24);
        let hv = HouseholderVectors::random_full(d, rng);
        let x = Mat::randn(d, m, rng);
        let engine = Engine::FastH { k };
        let y = engine.apply(&hv, &x);
        // Isometry per column.
        for j in 0..m {
            let nx: f32 = x.col(j).iter().map(|v| v * v).sum::<f32>().sqrt();
            let ny: f32 = y.col(j).iter().map(|v| v * v).sum::<f32>().sqrt();
            if (nx - ny).abs() > 1e-3 * nx.max(1.0) {
                return Err(format!("column {j} norm changed: {nx} -> {ny}"));
            }
        }
        // Transpose-apply inverts.
        let back = fasth::householder::fasth::fasth_apply_transpose(&hv, &y, k);
        assert_close(back.data(), x.data(), 2e-3, 2e-3)
    });
}

#[test]
fn property_partial_reflections() {
    // n < d reflections (the expressiveness/cost trade-off of §5) works
    // across engines.
    check("partial_reflections", 10, |rng| {
        let d = 8 + rng.below(60);
        let n = 1 + rng.below(d);
        let m = 1 + rng.below(8);
        let k = 1 + rng.below(12);
        let hv = HouseholderVectors::random(d, n, rng);
        let x = Mat::randn(d, m, rng);
        let want = Engine::Sequential.apply(&hv, &x);
        let a = Engine::FastH { k }.apply(&hv, &x);
        let b = Engine::Parallel.apply(&hv, &x);
        assert_close(a.data(), want.data(), 2e-3, 2e-3)?;
        assert_close(b.data(), want.data(), 2e-3, 2e-3)
    });
}

#[test]
fn gradient_descent_trajectory_identical_across_engines() {
    // Running T SGD steps under FastH vs sequential gives the same
    // trajectory — the strongest form of "same output, just faster".
    let mut rng = Rng::new(0xE2);
    let (d, m, t_steps) = (48, 8, 5);
    let hv0 = HouseholderVectors::random_full(d, &mut rng);
    let x = Mat::randn(d, m, &mut rng);
    let g = Mat::randn(d, m, &mut rng);

    let run = |engine: Engine| {
        let mut hv = hv0.clone();
        for _ in 0..t_steps {
            let (_a, _dx, dv) = engine.step(&hv, &x, &g);
            hv.sgd_step(&dv, 0.01);
        }
        hv
    };
    let hv_seq = run(Engine::Sequential);
    let hv_fast = run(Engine::FastH { k: 8 });
    assert_close(hv_fast.v.data(), hv_seq.v.data(), 5e-3, 5e-3).unwrap();
}

#[test]
fn zero_and_duplicate_vectors_are_handled() {
    // Degenerate inputs: zero vectors (identity reflections) interleaved
    // with duplicated vectors (H·H = I pairs).
    let mut rng = Rng::new(0xE3);
    let d = 24;
    let mut v = Mat::zeros(d, 6);
    let col: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
    v.set_col(1, &col);
    v.set_col(2, &col); // H2·H3 = I
    let col2: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
    v.set_col(4, &col2);
    let hv = HouseholderVectors::new(v);
    let x = Mat::randn(d, 5, &mut rng);
    // Product reduces to H(col2) alone.
    let mut want = x.clone();
    fasth::householder::vectors::apply_reflection_inplace(&col2, &mut want);
    for engine in [Engine::Sequential, Engine::Parallel, Engine::FastH { k: 4 }] {
        let got = engine.apply(&hv, &x);
        assert_close(got.data(), want.data(), 1e-3, 1e-3)
            .unwrap_or_else(|e| panic!("{}: {e}", engine.name()));
    }
}
