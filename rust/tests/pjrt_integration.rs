//! PJRT integration: load every AOT artifact, execute it, and cross-check
//! against the native Rust engines — the full L1/L2 (JAX/Pallas) ↔ L3
//! (Rust) numerical contract.
//!
//! Requires `make artifacts` (artifacts/manifest.json). Tests skip with a
//! message when artifacts are absent so `cargo test` works on a fresh
//! clone.

use fasth::householder::{seq, HouseholderVectors};
use fasth::linalg::Mat;
use fasth::runtime::pjrt::{ArtifactEngine, Tensor};
use fasth::svd::SvdParam;
use fasth::util::prop::assert_close;
use fasth::util::Rng;
use std::path::Path;

fn engine() -> Option<ArtifactEngine> {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts/manifest.json missing — run `make artifacts`");
        return None;
    }
    let engine = ArtifactEngine::open(dir).expect("open artifacts");
    if !engine.backend_available() {
        eprintln!("SKIP: PJRT execution backend not compiled into this build");
        return None;
    }
    Some(engine)
}

/// Build a param whose σ is interesting and matches artifact batch m.
fn setup(d: usize, seed: u64) -> (SvdParam, Mat, Mat) {
    let mut rng = Rng::new(seed);
    let mut param = SvdParam::random_full(d, &mut rng);
    for s in param.sigma.iter_mut() {
        *s = 0.8 + 0.4 * rng.uniform() as f32;
    }
    let x = Mat::randn(d, 32, &mut rng);
    let g = Mat::randn(d, 32, &mut rng);
    (param, x, g)
}

#[test]
fn orthogonal_apply_matches_native() {
    let Some(engine) = engine() else { return };
    for d in engine.manifest().sizes() {
        let name = format!("orthogonal_apply_{d}");
        if engine.entry(&name).is_none() {
            continue;
        }
        let mut rng = Rng::new(d as u64);
        let hv = HouseholderVectors::random_full(d, &mut rng);
        let x = Mat::randn(d, 32, &mut rng);
        let got = engine
            .run1(&name, &[Tensor::M(hv.v.clone()), Tensor::M(x.clone())])
            .expect("run");
        let want = seq::seq_apply(&hv, &x);
        assert_close(got.data(), want.data(), 2e-3, 2e-3)
            .unwrap_or_else(|e| panic!("{name}: {e}"));
    }
}

#[test]
fn svd_apply_and_inverse_match_native() {
    let Some(engine) = engine() else { return };
    let d = *engine.manifest().sizes().first().expect("at least one size");
    let (param, x, _g) = setup(d, 0x9A);
    let inputs = vec![
        Tensor::M(param.u.v.clone()),
        Tensor::M(param.v.v.clone()),
        Tensor::V(param.sigma.clone()),
        Tensor::M(x.clone()),
    ];
    let k = engine.entry(&format!("svd_apply_{d}")).unwrap().k;

    let got_apply = engine.run1(&format!("svd_apply_{d}"), &inputs).expect("apply");
    let want_apply = param.apply(&x, k);
    assert_close(got_apply.data(), want_apply.data(), 5e-3, 5e-3).unwrap();

    let got_inv = engine.run1(&format!("svd_inverse_{d}"), &inputs).expect("inverse");
    let want_inv = param.apply_inverse(&x, k);
    assert_close(got_inv.data(), want_inv.data(), 5e-3, 5e-3).unwrap();

    // Round trip through the artifacts: inverse(apply(x)) = x.
    let mut inputs2 = inputs.clone();
    inputs2[3] = Tensor::M(got_apply);
    let back = engine.run1(&format!("svd_inverse_{d}"), &inputs2).expect("roundtrip");
    assert_close(back.data(), x.data(), 1e-2, 1e-2).unwrap();
}

#[test]
fn gradient_step_artifact_matches_native_backward() {
    let Some(engine) = engine() else { return };
    let d = *engine.manifest().sizes().first().unwrap();
    let name = format!("gradient_step_{d}");
    let Some(entry) = engine.entry(&name) else { return };
    let k = entry.k;
    let mut rng = Rng::new(0x9B);
    let hv = HouseholderVectors::random_full(d, &mut rng);
    let x = Mat::randn(d, 32, &mut rng);
    let g = Mat::randn(d, 32, &mut rng);
    let outs = engine
        .run(&name, &[Tensor::M(hv.v.clone()), Tensor::M(x.clone()), Tensor::M(g.clone())])
        .expect("run");
    assert_eq!(outs.len(), 3); // (A, dV, dX)
    let a = outs[0].as_mat().unwrap();
    let dv = outs[1].as_mat().unwrap();
    let dx = outs[2].as_mat().unwrap();

    let (a_n, cache) = fasth::householder::fasth::fasth_forward(&hv, &x, k.min(d));
    let (dx_n, dv_n) = fasth::householder::fasth::fasth_backward(&hv, &cache, &g);
    assert_close(a.data(), a_n.data(), 2e-3, 2e-3).unwrap();
    assert_close(dx.data(), dx_n.data(), 5e-3, 5e-3).unwrap();
    assert_close(dv.data(), dv_n.data(), 1e-2, 1e-2).unwrap();
}

#[test]
fn svd_layer_step_artifact_runs_and_matches() {
    let Some(engine) = engine() else { return };
    let d = *engine.manifest().sizes().first().unwrap();
    let name = format!("svd_layer_step_{d}");
    let Some(entry) = engine.entry(&name) else { return };
    let k = entry.k;
    let (param, x, g) = setup(d, 0x9C);
    let outs = engine
        .run(
            &name,
            &[
                Tensor::M(param.u.v.clone()),
                Tensor::M(param.v.v.clone()),
                Tensor::V(param.sigma.clone()),
                Tensor::M(x.clone()),
                Tensor::M(g.clone()),
            ],
        )
        .expect("run");
    assert_eq!(outs.len(), 5); // (Y, dVu, dVv, dΣ, dX)
    let y = outs[0].as_mat().unwrap();
    let (y_n, cache) = param.forward(&x, k);
    let (dx_n, grads_n) = param.backward(&cache, &g);
    assert_close(y.data(), y_n.data(), 5e-3, 5e-3).unwrap();
    assert_close(outs[1].as_mat().unwrap().data(), grads_n.du.data(), 2e-2, 2e-2).unwrap();
    assert_close(outs[2].as_mat().unwrap().data(), grads_n.dv.data(), 2e-2, 2e-2).unwrap();
    match &outs[3] {
        Tensor::V(ds) => assert_close(ds, &grads_n.dsigma, 2e-2, 2e-2).unwrap(),
        _ => panic!("dΣ should be rank-1"),
    }
    assert_close(outs[4].as_mat().unwrap().data(), dx_n.data(), 5e-3, 5e-3).unwrap();
}

#[test]
fn shape_validation_rejects_bad_inputs() {
    let Some(engine) = engine() else { return };
    let d = *engine.manifest().sizes().first().unwrap();
    let name = format!("orthogonal_apply_{d}");
    // Wrong arity.
    assert!(engine.run(&name, &[Tensor::M(Mat::zeros(d, d))]).is_err());
    // Wrong shape.
    assert!(engine
        .run(&name, &[Tensor::M(Mat::zeros(d, d)), Tensor::M(Mat::zeros(d + 1, 32))])
        .is_err());
    // Unknown artifact.
    assert!(engine.run("no_such_artifact", &[]).is_err());
}
