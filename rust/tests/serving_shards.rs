//! Sharded-serving properties: batcher fairness under a full-flush
//! burst, rendezvous placement stability as the shard count grows, and
//! multi-model traffic across a real sharded TCP server (fixed and
//! adaptive deadlines).

use fasth::coordinator::{
    rendezvous_place, BatcherConfig, Call, Client, DynamicBatcher, ExecEngine, ModelRegistry,
    OpKind, Request, Server, ServerConfig,
};
use fasth::util::Rng;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

fn req(id: u64, model: &str) -> Request {
    Request {
        id,
        model: model.into(),
        op: OpKind::Apply,
        column: vec![1.0, 2.0],
        ttl_ms: None,
        rank: None,
        timing: false,
        sampled: false,
    }
}

/// A sustained full-flush burst on one `(model, op)` key must not delay
/// a deadline-expired key beyond `max_wait + ε`. (The pre-fairness
/// batcher checked full queues before expired ones, so a hot key that
/// kept refilling to `max_batch` starved singleton keys indefinitely.)
#[test]
fn full_flush_burst_cannot_starve_expired_key() {
    let max_wait = Duration::from_millis(25);
    let b = Arc::new(DynamicBatcher::new(BatcherConfig {
        max_batch: 4,
        max_wait,
        ..Default::default()
    }));
    let stop = Arc::new(AtomicBool::new(false));

    // Producer: keep the burst key's queue at/above max_batch.
    let producer = {
        let b = b.clone();
        let stop = stop.clone();
        std::thread::spawn(move || {
            let mut id = 1000u64;
            while !stop.load(Ordering::Relaxed) {
                for _ in 0..4 {
                    b.submit(req(id, "burst"));
                    id += 1;
                }
                std::thread::sleep(Duration::from_micros(200));
            }
        })
    };

    // Single consumer (the sharp case: one worker, so every burst batch
    // competes head-on with the victim).
    let (tx, rx) = mpsc::channel();
    let consumer = {
        let b = b.clone();
        std::thread::spawn(move || {
            let mut full_bursts = 0u32;
            while let Some(batch) = b.next_batch() {
                if batch.model == "victim" {
                    let _ = tx.send((Instant::now(), full_bursts));
                } else if batch.full {
                    full_bursts += 1;
                }
            }
        })
    };

    // Let the burst reach steady state, then submit one victim request.
    std::thread::sleep(Duration::from_millis(20));
    let t0 = Instant::now();
    b.submit(req(1, "victim"));
    let (t_served, full_bursts) = rx
        .recv_timeout(Duration::from_secs(5))
        .expect("victim starved: never flushed under the burst");
    let waited = t_served.duration_since(t0);
    stop.store(true, Ordering::Relaxed);
    producer.join().unwrap();
    b.close();
    consumer.join().unwrap();

    // Generous ε for CI scheduling noise — the regression mode is
    // unbounded starvation, not tens of milliseconds.
    assert!(
        waited <= max_wait + Duration::from_millis(150),
        "deadline overshoot: waited {waited:?} (max_wait {max_wait:?})"
    );
    assert!(full_bursts >= 3, "burst never contended (only {full_bursts} full flushes)");
}

/// Growing S → S+1 shards must remap roughly 1/(S+1) of model names —
/// and every moved name must move *to* the new shard (the rendezvous
/// property; a modular hash reshuffles almost everything).
#[test]
fn rendezvous_growth_moves_about_one_over_s() {
    let names: Vec<String> = (0..1000).map(|i| format!("model_{i}")).collect();
    for s in [2usize, 4, 8] {
        let mut moved = 0;
        for name in &names {
            let old = rendezvous_place(s, name);
            let new = rendezvous_place(s + 1, name);
            if old != new {
                assert_eq!(new, s, "'{name}' moved {old}→{new}, not to the new shard {s}");
                moved += 1;
            }
        }
        let frac = moved as f64 / names.len() as f64;
        let expect = 1.0 / (s as f64 + 1.0);
        assert!(moved > 0, "no names moved at S={s} — new shard unused");
        assert!(frac <= expect + 0.08, "S={s}: moved {frac:.3}, expected ≈{expect:.3}");
    }
}

/// Many models across 3 shards over real TCP: concurrent mixed traffic
/// (square apply/inverse + rect apply/pinv) all completes, and stats
/// report one depth slot per shard.
#[test]
fn multi_model_traffic_across_three_shards() {
    let registry = Arc::new(ModelRegistry::new());
    for i in 0..4 {
        registry.create(&format!("sq_{i}"), 12, ExecEngine::Native { k: 4 }, 50 + i);
    }
    for i in 0..4 {
        let name = format!("rc_{i}");
        registry.create_rect(&name, 18, 12, None, ExecEngine::Native { k: 4 }, 60 + i);
    }
    let config = ServerConfig::builder()
        .shards(3)
        .workers(1)
        .max_batch(8)
        .max_wait(Duration::from_millis(1))
        .max_queue_depth(10_000)
        .build()
        .unwrap();
    let server = Server::start(config, registry).unwrap();
    let addr = server.local_addr;
    let handles: Vec<_> = (0..4)
        .map(|c| {
            std::thread::spawn(move || {
                let mut rng = Rng::new(200 + c as u64);
                let mut client = Client::connect(&addr).unwrap();
                for i in 0..20 {
                    let col: Vec<f32> = (0..12).map(|_| rng.normal_f32()).collect();
                    if i % 2 == 0 {
                        let model = format!("sq_{}", i % 4);
                        let r = client.call(Call::apply(&model, col)).unwrap();
                        assert!(r.ok, "{model}: {:?}", r.error);
                        assert_eq!(r.column.len(), 12);
                    } else {
                        let model = format!("rc_{}", i % 4);
                        let fwd = client.call(Call::apply(&model, col)).unwrap();
                        assert!(fwd.ok, "{model}: {:?}", fwd.error);
                        assert_eq!(fwd.column.len(), 18);
                        let back = client.call(Call::pinv(&model, fwd.column)).unwrap();
                        assert!(back.ok, "{model} pinv: {:?}", back.error);
                        assert_eq!(back.column.len(), 12);
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let mut admin = Client::connect(&addr).unwrap();
    let stats = admin.admin("stats").unwrap();
    let j = fasth::util::json::Json::parse(&stats).unwrap();
    assert_eq!(j.get("shard_depth").as_arr().unwrap().len(), 3, "{stats}");
    assert!(j.get("per_op").get("pinv").get("count").as_usize().unwrap() > 0, "{stats}");
    let prom = admin.metrics_text().unwrap();
    assert!(prom.contains("orthoserve_shard_queue_depth{shard=\"2\"}"), "{prom}");
    server.stop();
}

/// The adaptive deadline serves correctly end-to-end: fast traffic
/// tightens the flush deadline (within clamps) without dropping or
/// corrupting responses.
#[test]
fn adaptive_deadline_server_roundtrips() {
    let registry = Arc::new(ModelRegistry::new());
    registry.create("m16", 16, ExecEngine::Native { k: 4 }, 77);
    let config = ServerConfig::builder()
        .shards(2)
        .workers(2)
        .batcher(BatcherConfig {
            max_batch: 16,
            max_wait: Duration::from_millis(5),
            adaptive: true,
            min_wait: Duration::from_micros(200),
            p50_fraction: 0.5,
        })
        .max_queue_depth(10_000)
        .build()
        .unwrap();
    let server = Server::start(config, registry).unwrap();
    let mut client = Client::connect(&server.local_addr).unwrap();
    let mut rng = Rng::new(42);
    // Sequential single calls: one batch (= one latency observation)
    // each, enough to cross the adaptation threshold deterministically.
    for _ in 0..32 {
        let col: Vec<f32> = (0..16).map(|_| rng.normal_f32()).collect();
        let r = client.call(Call::apply("m16", col)).unwrap();
        assert!(r.ok, "{:?}", r.error);
    }
    // Sub-millisecond d=16 batches must have pulled the serving shard's
    // deadline off the 5 ms ceiling (it stays ≥ the 200 µs floor).
    let shard = server.shards.shard_for("m16");
    let adapted = shard.batcher.current_wait();
    assert!(adapted < Duration::from_millis(5), "deadline never adapted: {adapted:?}");
    assert!(adapted >= Duration::from_micros(200), "deadline below floor: {adapted:?}");
    // Traffic under the adapted deadline still round-trips correctly.
    let calls: Vec<Call> = (0..64)
        .map(|_| Call::apply("m16", (0..16).map(|_| rng.normal_f32()).collect()))
        .collect();
    let responses = client.call_many(calls).unwrap();
    assert_eq!(responses.len(), 64);
    assert!(responses.iter().all(|r| r.ok));
    server.stop();
}
