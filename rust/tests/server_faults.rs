//! Chaos suite: deterministic fault injection against the full serving
//! stack. Every test drives a real server over real sockets with a
//! seeded [`FaultPlan`] and asserts the fault-tolerance contract:
//! every request gets exactly one response, no client ever hangs, and
//! the server keeps serving after every injected failure.
//!
//! The nightly chaos CI lane replays this suite under rotating seeds
//! via the `FASTH_FAULT_SEED` environment variable (see
//! `seeded_chaos_every_request_answered_and_server_survives`).

use fasth::coordinator::{
    Call, Client, ClientConfig, ErrorCode, ExecEngine, FaultPlan, ModelRegistry, OpKind, Request,
    Response, RetryPolicy, Server, ServerConfig,
};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// The chaos seed: `FASTH_FAULT_SEED` when set (the nightly lane
/// rotates it by date), a fixed default otherwise so plain `cargo test`
/// is reproducible.
fn chaos_seed() -> u64 {
    std::env::var("FASTH_FAULT_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0xFA17)
}

fn request_line(id: u64, model: &str, column: Vec<f32>) -> String {
    Request {
        id,
        model: model.into(),
        op: OpKind::Apply,
        column,
        ttl_ms: None,
        rank: None,
        timing: false,
        sampled: false,
    }
        .to_json()
}

fn registry_with_m8() -> Arc<ModelRegistry> {
    let registry = Arc::new(ModelRegistry::new());
    registry.create("m8", 8, ExecEngine::Native { k: 4 }, 0xFA17);
    registry
}

/// Every 4th batch panics. With one worker, one-column batches, and a
/// sequential client, batch ordinals are exactly the request ordinals:
/// requests 4, 8, 12, 16, 20 fail with a structured `internal_panic`
/// envelope, every other request succeeds, the panicking workers are
/// respawned, and the server serves normally afterwards.
#[test]
fn panics_are_isolated_and_workers_respawn() {
    let config = ServerConfig::builder()
        .shards(1)
        .workers(1)
        .max_batch(1)
        .max_wait(Duration::from_millis(1))
        .faults(FaultPlan::new().panic_every(4))
        .build()
        .unwrap();
    let server = Server::start(config, registry_with_m8()).unwrap();

    let mut client = Client::connect(&server.local_addr).unwrap();
    let n = 20u64;
    let mut failed = 0u64;
    for i in 1..=n {
        let resp = client.call(Call::apply("m8", vec![0.5; 8])).unwrap();
        if resp.ok {
            assert_eq!(resp.column.len(), 8, "request {i}");
        } else {
            failed += 1;
            assert_eq!(resp.code, Some(ErrorCode::InternalPanic), "request {i}: {:?}", resp.error);
            assert!(resp.retryable, "internal_panic must be marked retryable");
            let msg = resp.error.as_deref().unwrap_or("");
            assert!(msg.contains("panic"), "request {i}: unhelpful error {msg:?}");
        }
    }
    assert_eq!(failed, n / 4, "panic_every(4) over {n} one-column batches");
    assert_eq!(server.metrics.worker_panics.load(Ordering::Relaxed), n / 4);
    assert_eq!(server.metrics.err_code_count(ErrorCode::InternalPanic), n / 4);

    // The supervisor replaces every panicked worker (the sweep is
    // asynchronous; poll briefly).
    let t0 = Instant::now();
    while server.metrics.worker_respawns.load(Ordering::Relaxed) < n / 4 {
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "supervisor respawned only {} of {} panicked workers",
            server.metrics.worker_respawns.load(Ordering::Relaxed),
            n / 4
        );
        std::thread::sleep(Duration::from_millis(5));
    }

    // Still serving after every panic.
    let resp = client.call(Call::apply("m8", vec![0.25; 8])).unwrap();
    assert!(resp.ok, "server dead after panics: {:?}", resp.error);
    server.stop();
}

/// Injected service latency makes queued requests outlive their TTL:
/// the batcher sheds them at dequeue with `deadline_exceeded` instead
/// of serving stale answers, while the TTL-less request rides normally.
#[test]
fn expired_requests_are_shed_with_deadline_exceeded() {
    let config = ServerConfig::builder()
        .shards(1)
        .workers(1)
        .max_batch(8)
        .max_wait(Duration::from_millis(1))
        .faults(FaultPlan::new().delay_every(1, Duration::from_millis(60)))
        .build()
        .unwrap();
    let server = Server::start(config, registry_with_m8()).unwrap();

    let mut client = Client::connect(&server.local_addr).unwrap();
    // No TTL: occupies the single worker for the injected 60 ms.
    let slow_id = client.send(&Call::apply("m8", vec![0.5; 8])).unwrap();
    std::thread::sleep(Duration::from_millis(15));
    // These queue behind the delayed batch and expire (10 ms TTL) long
    // before the worker frees up.
    let doomed = 4usize;
    let mut ids = Vec::new();
    for _ in 0..doomed {
        let call = Call::apply("m8", vec![0.5; 8]).ttl(Duration::from_millis(10));
        ids.push(client.send(&call).unwrap());
    }
    let slow = client.wait_for(slow_id).unwrap();
    assert!(slow.ok, "TTL-less request must ride: {:?}", slow.error);
    for id in ids {
        let resp = client.wait_for(id).unwrap();
        assert!(!resp.ok, "request {id} should have been shed");
        assert_eq!(resp.code, Some(ErrorCode::DeadlineExceeded), "{:?}", resp.error);
        assert!(resp.retryable, "deadline_exceeded must be marked retryable");
        assert!(
            resp.error.as_deref().unwrap_or("").contains("expired"),
            "unhelpful shed message: {:?}",
            resp.error
        );
    }
    assert_eq!(server.metrics.requests_shed_deadline.load(Ordering::Relaxed), doomed as u64);
    assert_eq!(server.metrics.err_code_count(ErrorCode::DeadlineExceeded), doomed as u64);
    server.stop();
}

/// Every 3rd non-empty flush drops the connection instead of writing.
/// Clients see clean EOFs (never hangs), reconnects keep working, and
/// the server keeps serving throughout.
#[test]
fn dropped_connections_recover_on_reconnect() {
    let config = ServerConfig::builder()
        .shards(1)
        .workers(1)
        .max_batch(4)
        .max_wait(Duration::from_millis(1))
        .faults(FaultPlan::new().drop_conn_every(3))
        .build()
        .unwrap();
    let server = Server::start(config, registry_with_m8()).unwrap();

    let mut served = 0usize;
    let mut dropped = 0usize;
    for id in 1..=12u64 {
        // Raw connection, no handshake: exactly one flush per response,
        // so the drop schedule advances once per connection.
        let stream = TcpStream::connect(server.local_addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = BufWriter::new(stream);
        writeln!(writer, "{}", request_line(id, "m8", vec![0.5; 8])).unwrap();
        writer.flush().unwrap();
        let mut line = String::new();
        match reader.read_line(&mut line) {
            Ok(0) => dropped += 1, // injected drop: clean EOF, no hang
            Ok(_) => {
                let resp = Response::from_json(line.trim()).unwrap();
                assert!(resp.ok, "conn {id}: {:?}", resp.error);
                assert_eq!(resp.id, id);
                served += 1;
            }
            Err(e) => panic!("conn {id}: read failed with {e} instead of EOF or response"),
        }
    }
    assert!(dropped >= 1, "drop_conn_every(3) never fired over 12 connections");
    assert!(served >= 6, "only {served}/12 connections served around the injected drops");
    server.stop();
}

/// `Server::stop` drains: work accepted before the stop completes and
/// its responses reach the client even though the worker is slowed by
/// injected latency, and the observed drain time lands in the metric.
#[test]
fn graceful_drain_flushes_accepted_work() {
    let config = ServerConfig::builder()
        .shards(1)
        .workers(1)
        .max_batch(1)
        .max_wait(Duration::from_millis(1))
        .faults(FaultPlan::new().delay_every(1, Duration::from_millis(10)))
        .build()
        .unwrap();
    let server = Server::start(config, registry_with_m8()).unwrap();

    let mut client = Client::connect(&server.local_addr).unwrap();
    let call = Call::apply("m8", vec![0.5; 8]);
    let ids: Vec<u64> = (0..8).map(|_| client.send(&call).unwrap()).collect();

    // Wait for the reactor to admit all 8 (frames still in the socket
    // buffer when the drain flag flips would be rejected, not drained),
    // then stop while ~10 ms/batch of accepted work is still queued.
    let metrics = server.metrics.clone();
    let t0 = Instant::now();
    while metrics.requests.load(Ordering::Relaxed) < ids.len() as u64 {
        assert!(t0.elapsed() < Duration::from_secs(5), "requests never admitted");
        std::thread::sleep(Duration::from_millis(1));
    }
    std::thread::sleep(Duration::from_millis(20));
    let stopper = std::thread::spawn(move || server.stop());
    for id in ids {
        let resp = client.wait_for(id).unwrap();
        assert!(resp.ok, "accepted request {id} lost in drain: {:?}", resp.error);
    }
    stopper.join().unwrap();
    assert!(
        metrics.drain_duration_us.load(Ordering::Relaxed) > 0,
        "drain_duration_us never recorded"
    );
}

/// Once a drain begins, new requests are answered with a structured
/// `draining` rejection (retryable — another instance could serve
/// them) while already-accepted work still completes.
#[test]
fn draining_rejects_new_requests_while_finishing_accepted_ones() {
    let config = ServerConfig::builder()
        .shards(1)
        .workers(1)
        .max_batch(8)
        .max_wait(Duration::from_millis(1))
        .faults(FaultPlan::new().delay_every(1, Duration::from_millis(300)))
        .build()
        .unwrap();
    let server = Server::start(config, registry_with_m8()).unwrap();

    // Both connections exist before the drain (the accept loop stops
    // taking new sockets once draining starts).
    let mut client_a = Client::connect(&server.local_addr).unwrap();
    let mut client_b = Client::connect(&server.local_addr).unwrap();

    // A's request is executing (held ~300 ms by injected latency) when
    // the drain begins.
    let id_a = client_a.send(&Call::apply("m8", vec![0.5; 8])).unwrap();
    std::thread::sleep(Duration::from_millis(50));
    let stopper = std::thread::spawn(move || server.stop());
    std::thread::sleep(Duration::from_millis(50));

    let resp_b = client_b.call(Call::apply("m8", vec![0.5; 8])).unwrap();
    assert!(!resp_b.ok, "request sent mid-drain must be rejected");
    assert_eq!(resp_b.code, Some(ErrorCode::Draining), "{:?}", resp_b.error);
    assert!(resp_b.retryable, "draining must be marked retryable");

    let resp_a = client_a.wait_for(id_a).unwrap();
    assert!(resp_a.ok, "accepted request dropped by drain: {:?}", resp_a.error);
    stopper.join().unwrap();
}

/// The seeded chaos run the nightly lane replays: a mixed panic +
/// latency plan derived from `FASTH_FAULT_SEED`, retrying clients
/// hammering two shards from four threads. The contract under chaos:
/// every call returns exactly one response (no hangs, no transport
/// errors — the plan injects no connection drops), the response ledger
/// balances, panics actually fired, and the server serves and stops
/// cleanly afterwards.
#[test]
fn seeded_chaos_every_request_answered_and_server_survives() {
    let seed = chaos_seed();
    let plan = FaultPlan::from_seed(seed);
    let registry = Arc::new(ModelRegistry::new());
    registry.create("m8", 8, ExecEngine::Native { k: 4 }, seed);
    registry.create("m16", 16, ExecEngine::Native { k: 8 }, seed ^ 1);
    let config = ServerConfig::builder()
        .shards(2)
        .workers(2)
        .reactors(2)
        .max_batch(4)
        .max_wait(Duration::from_millis(1))
        .max_queue_depth(1000)
        .faults(plan.clone())
        .build()
        .unwrap();
    let server = Server::start(config, registry).unwrap();
    let addr = server.local_addr;

    let threads = 4usize;
    let per_thread = 25usize;
    let handles: Vec<_> = (0..threads)
        .map(|t| {
            std::thread::spawn(move || {
                let cfg = ClientConfig {
                    read_timeout: Duration::from_secs(10),
                    retry: Some(RetryPolicy {
                        jitter_seed: seed ^ t as u64,
                        base_backoff: Duration::from_micros(200),
                        ..Default::default()
                    }),
                    ..Default::default()
                };
                let mut client = Client::connect_with(&addr, cfg).unwrap();
                let mut ok = 0usize;
                for i in 0..per_thread {
                    let (model, d) = if (t + i) % 2 == 0 { ("m8", 8) } else { ("m16", 16) };
                    // Exactly-one-response: call() must always return —
                    // a hang here trips the read timeout and panics.
                    let resp = client.call(Call::apply(model, vec![0.5; d])).unwrap();
                    if resp.ok {
                        ok += 1;
                    } else {
                        // Only the injected fault surfaces; never a
                        // parse or routing error.
                        assert_eq!(
                            resp.code,
                            Some(ErrorCode::InternalPanic),
                            "thread {t} call {i}: {:?}",
                            resp.error
                        );
                    }
                }
                ok
            })
        })
        .collect();
    let served: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    assert!(served > 0, "seed {seed:#x}: nothing served under plan {plan:?}");

    // Quiescent ledger: every request the reactors admitted was
    // answered exactly once, ok or err — nothing double-counted,
    // nothing lost (retries count on both sides).
    let m = &server.metrics;
    let requests = m.requests.load(Ordering::Relaxed);
    let ok = m.responses_ok.load(Ordering::Relaxed);
    let err = m.responses_err.load(Ordering::Relaxed);
    assert_eq!(
        requests,
        ok + err,
        "seed {seed:#x}: response ledger out of balance (requests {requests}, ok {ok}, err {err})"
    );
    assert!(
        m.worker_panics.load(Ordering::Relaxed) >= 1,
        "seed {seed:#x}: plan {plan:?} never panicked over {requests} requests"
    );

    // Still serves after the storm (retry rides over a residual panic).
    let cfg = ClientConfig { retry: Some(RetryPolicy::default()), ..Default::default() };
    let mut client = Client::connect_with(&addr, cfg).unwrap();
    let survived = (0..5).any(|_| {
        client.call(Call::apply("m8", vec![0.5; 8])).map(|r| r.ok).unwrap_or(false)
    });
    assert!(survived, "seed {seed:#x}: server unserviceable after chaos");
    server.stop();
}
