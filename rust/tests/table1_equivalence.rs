//! Table 1 integration: every matrix operation computed by the standard
//! dense method and by the SVD route must agree numerically (exactly the
//! correspondence the paper's Table 1 asserts).

use fasth::householder::{Engine, HouseholderVectors};
use fasth::linalg::{cayley, expm, gemm, lu, Mat};
use fasth::svd::ops::{
    op_step, standard_step, sym_apply, sym_materialize, MatrixOp, OpEngine, OpWorkload,
};
use fasth::util::prop::assert_close;
use fasth::util::Rng;

#[test]
fn inverse_row() {
    let mut rng = Rng::new(0x7A1);
    let wl = OpWorkload::new(48, 8, &mut rng);
    let std = standard_step(MatrixOp::Inverse, &wl.w, &wl.x, &wl.g);
    // Direct check against LU: W⁻¹X.
    let want = gemm::matmul(&lu::inverse(&wl.w).unwrap(), &wl.x);
    assert_close(std.y.data(), want.data(), 1e-3, 1e-2).unwrap();
    for engine in [
        OpEngine::Svd(Engine::FastH { k: 8 }),
        OpEngine::Svd(Engine::Sequential),
        OpEngine::Svd(Engine::Parallel),
    ] {
        let svd = op_step(MatrixOp::Inverse, engine, &wl.w, &wl.param, &wl.x, &wl.g);
        assert_close(svd.y.data(), want.data(), 5e-2, 5e-2)
            .unwrap_or_else(|e| panic!("{}: {e}", engine.name()));
    }
}

#[test]
fn determinant_row() {
    let mut rng = Rng::new(0x7A2);
    let wl = OpWorkload::new(40, 4, &mut rng);
    let (sign_lu, log_lu) = lu::slogdet(&wl.w);
    let (sign_svd, log_svd) = wl.param.slogdet();
    assert_eq!(sign_lu.signum(), sign_svd.signum(), "determinant sign");
    assert!(
        (log_lu - log_svd).abs() < 1e-2 * log_lu.abs().max(1.0),
        "log|det|: LU {log_lu} vs SVD {log_svd}"
    );
    // O(d) vs O(d³): same number.
    let std = standard_step(MatrixOp::Determinant, &wl.w, &wl.x, &wl.g);
    assert!((std.scalar - log_svd).abs() < 1e-2 * log_svd.abs().max(1.0));
}

#[test]
fn expm_row_symmetric_form() {
    let mut rng = Rng::new(0x7A3);
    let d = 32;
    let u = HouseholderVectors::random_full(d, &mut rng);
    let sigma: Vec<f32> = (0..d).map(|i| -0.5 + (i as f32) / d as f32).collect();
    let w = sym_materialize(&u, &sigma);
    let x = Mat::randn(d, 6, &mut rng);
    let want = gemm::matmul(&expm::expm(&w), &x);
    let got = sym_apply(&u, &MatrixOp::Expm.transform_sigma(&sigma), &x, 8);
    assert_close(got.data(), want.data(), 5e-2, 5e-2).unwrap();
}

#[test]
fn cayley_row_symmetric_form() {
    let mut rng = Rng::new(0x7A4);
    let d = 28;
    let u = HouseholderVectors::random_full(d, &mut rng);
    let sigma: Vec<f32> = (0..d).map(|i| 0.1 + 0.02 * i as f32).collect();
    let w = sym_materialize(&u, &sigma);
    let x = Mat::randn(d, 5, &mut rng);
    let want = gemm::matmul(&cayley::cayley(&w).unwrap(), &x);
    let got = sym_apply(&u, &MatrixOp::Cayley.transform_sigma(&sigma), &x, 7);
    assert_close(got.data(), want.data(), 5e-2, 5e-2).unwrap();
}

#[test]
fn spectral_clipping_controls_condition_number() {
    // The spectral-RNN use case: after clip_sigma(ε), κ(W) ≤ (1+ε)/(1−ε).
    let mut rng = Rng::new(0x7A5);
    let mut param = fasth::svd::SvdParam::random_full(24, &mut rng);
    for s in param.sigma.iter_mut() {
        *s = 0.1 + 3.0 * rng.uniform() as f32;
    }
    param.clip_sigma(0.05);
    let w = param.materialize();
    let svd = fasth::svd::jacobi::svd(&w);
    let kappa = svd.sigma[0] / svd.sigma[23];
    let bound = 1.05 / 0.95 + 0.02;
    assert!(kappa <= bound, "κ = {kappa} > {bound}");
}

#[test]
fn jacobi_svd_agrees_with_reparameterized_spectrum() {
    // Computing the SVD the O(d³) way recovers the spectrum we never had
    // to compute — the paper's whole premise, verified.
    let mut rng = Rng::new(0x7A6);
    let mut param = fasth::svd::SvdParam::random_full(16, &mut rng);
    for (i, s) in param.sigma.iter_mut().enumerate() {
        *s = 0.5 + 0.1 * i as f32;
    }
    let w = param.materialize();
    let svd = fasth::svd::jacobi::svd(&w);
    let mut want = param.sigma.clone();
    want.sort_by(|a, b| b.partial_cmp(a).unwrap());
    for (got, want) in svd.sigma.iter().zip(&want) {
        assert!((got - want).abs() < 1e-3 * want, "σ {got} vs {want}");
    }
}
