//! Coordinator end-to-end over real TCP: batching semantics, response
//! conservation under concurrency, sharded routing (shards ≥ 2 with a
//! rectangular model served via apply/pinv), mixed exact + truncated
//! (`rank`) traffic, PJRT-backed serving when artifacts exist, and
//! backpressure.

use fasth::coordinator::{Call, Client, ExecEngine, ModelRegistry, OpKind, Server, ServerConfig};
use fasth::util::prop::assert_close;
use fasth::util::Rng;
use std::sync::Arc;
use std::time::Duration;

/// A 2-shard server with one square model `svd_{d}` and one tall
/// rectangular model `rect_{2d}x{d}` (full rank).
fn native_server(d: usize, max_batch: usize) -> Server {
    let registry = Arc::new(ModelRegistry::new());
    registry.create(&format!("svd_{d}"), d, ExecEngine::Native { k: 8 }, 0xE2E);
    registry.create_rect(
        &format!("rect_{}x{d}", 2 * d),
        2 * d,
        d,
        None,
        ExecEngine::Native { k: 8 },
        0xE2E + 1,
    );
    let config = ServerConfig::builder()
        .shards(2)
        .workers(2)
        .max_batch(max_batch)
        .max_wait(Duration::from_millis(2))
        .max_queue_depth(10_000)
        .build()
        .expect("valid config");
    Server::start(config, registry).expect("start server")
}

#[test]
fn apply_inverse_roundtrip_over_tcp() {
    let server = native_server(16, 8);
    let mut client = Client::connect(&server.local_addr).unwrap();
    let mut rng = Rng::new(1);
    for _ in 0..5 {
        let col: Vec<f32> = (0..16).map(|_| rng.normal_f32()).collect();
        let fwd = client.call(Call::apply("svd_16", col.clone())).unwrap();
        assert!(fwd.ok);
        let back = client.call(Call::inverse("svd_16", fwd.column)).unwrap();
        assert!(back.ok);
        assert_close(&back.column, &col, 1e-2, 1e-2).unwrap();
    }
    server.stop();
}

#[test]
fn rect_model_apply_pinv_roundtrip_over_tcp() {
    // The PR-3 follow-up: rectangular models served end-to-end. Tall
    // full-rank ⇒ pinv is a left inverse, so the round trip is exact up
    // to FastH tolerance; the widths change across the wire (16 in, 32
    // out for apply; the reverse for pinv).
    let server = native_server(16, 8);
    let mut client = Client::connect(&server.local_addr).unwrap();
    let mut rng = Rng::new(7);
    for _ in 0..3 {
        let col: Vec<f32> = (0..16).map(|_| rng.normal_f32()).collect();
        let fwd = client.call(Call::apply("rect_32x16", col.clone())).unwrap();
        assert!(fwd.ok, "{:?}", fwd.error);
        assert_eq!(fwd.column.len(), 32, "apply must widen 16→32");
        let back = client.call(Call::pinv("rect_32x16", fwd.column)).unwrap();
        assert!(back.ok, "{:?}", back.error);
        assert_eq!(back.column.len(), 16, "pinv must narrow 32→16");
        assert_close(&back.column, &col, 1e-2, 1e-2).unwrap();
    }
    // Square-only ops on the rect model surface a per-batch error.
    let bad = client.call(Call::expm("rect_32x16", vec![0.0; 16])).unwrap();
    assert!(!bad.ok);
    assert!(bad.error.unwrap().contains("square"));
    server.stop();
}

#[test]
fn stats_report_shard_depth_and_per_op_histograms() {
    let server = native_server(12, 4);
    let mut client = Client::connect(&server.local_addr).unwrap();
    let mut rng = Rng::new(9);
    let col: Vec<f32> = (0..12).map(|_| rng.normal_f32()).collect();
    for _ in 0..4 {
        assert!(client.call(Call::apply("svd_12", col.clone())).unwrap().ok);
    }
    let rcol: Vec<f32> = (0..12).map(|_| rng.normal_f32()).collect();
    assert!(client.call(Call::apply("rect_24x12", rcol)).unwrap().ok);
    let stats = client.admin("stats").unwrap();
    let j = fasth::util::json::Json::parse(&stats).unwrap();
    // One live-depth slot per shard.
    assert_eq!(j.get("shard_depth").as_arr().unwrap().len(), 2, "{stats}");
    // Per-op histograms counted the traffic by op.
    assert_eq!(j.get("per_op").get("apply").get("count").as_usize(), Some(5), "{stats}");
    assert_eq!(j.get("per_op").get("pinv").get("count").as_usize(), Some(0), "{stats}");
    assert!(j.get("per_op").get("apply").get("p50_us").as_f64().is_some(), "{stats}");
    server.stop();
}

#[test]
fn burst_gets_coalesced_into_batches() {
    let server = native_server(16, 16);
    let mut client = Client::connect(&server.local_addr).unwrap();
    let mut rng = Rng::new(2);
    let calls: Vec<Call> = (0..64)
        .map(|_| Call::apply("svd_16", (0..16).map(|_| rng.normal_f32()).collect()))
        .collect();
    let responses = client.call_many(calls).unwrap();
    assert_eq!(responses.len(), 64);
    assert!(responses.iter().all(|r| r.ok));
    let max_batch = responses.iter().map(|r| r.batch_size).max().unwrap();
    assert!(max_batch >= 8, "expected real batching, max batch {max_batch}");
    server.stop();
}

#[test]
fn conservation_under_concurrent_clients() {
    let server = native_server(12, 8);
    let addr = server.local_addr;
    let n_clients = 6;
    let per_client = 40;
    let handles: Vec<_> = (0..n_clients)
        .map(|c| {
            std::thread::spawn(move || {
                let mut rng = Rng::new(100 + c);
                let mut client = Client::connect(&addr).unwrap();
                // Interleave both shards' models from every client.
                let model = if c % 2 == 0 { "svd_12" } else { "rect_24x12" };
                let calls: Vec<Call> = (0..per_client)
                    .map(|_| Call::apply(model, (0..12).map(|_| rng.normal_f32()).collect()))
                    .collect();
                let rs = client.call_many(calls).unwrap();
                assert_eq!(rs.len(), per_client);
                rs.iter().filter(|r| r.ok).count()
            })
        })
        .collect();
    let total_ok: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    assert_eq!(total_ok, n_clients as usize * per_client);
    // Server-side accounting agrees.
    let mut admin = Client::connect(&addr).unwrap();
    let stats = admin.admin("stats").unwrap();
    let j = fasth::util::json::Json::parse(&stats).unwrap();
    assert_eq!(
        j.get("responses_ok").as_usize(),
        Some(n_clients as usize * per_client)
    );
    server.stop();
}

#[test]
fn expm_cayley_ops_served() {
    let server = native_server(12, 4);
    let mut client = Client::connect(&server.local_addr).unwrap();
    let mut rng = Rng::new(3);
    let col: Vec<f32> = (0..12).map(|_| rng.normal_f32()).collect();
    for op in [OpKind::Expm, OpKind::Cayley] {
        let r = client.call(Call::new("svd_12", op, col.clone())).unwrap();
        assert!(r.ok, "{op:?} failed: {:?}", r.error);
        assert_eq!(r.column.len(), 12);
        assert!(r.column.iter().all(|v| v.is_finite()));
    }
    server.stop();
}

#[test]
fn mixed_exact_and_rank_traffic_across_shards() {
    let server = native_server(16, 8);
    let mut client = Client::connect(&server.local_addr).unwrap();
    let mut rng = Rng::new(11);
    let cols: Vec<Vec<f32>> = (0..12)
        .map(|_| (0..16).map(|_| rng.normal_f32()).collect())
        .collect();
    // Baseline exact answers before any truncated traffic exists.
    let exact: Vec<Vec<f32>> = cols
        .iter()
        .map(|c| {
            let r = client.call(Call::apply("svd_16", c.clone())).unwrap();
            assert!(r.ok, "{:?}", r.error);
            r.column
        })
        .collect();
    // One pipelined burst interleaving exact, full-rank (r = d), and
    // truncated (rank = 4) lanes across both shards' models. The
    // batcher must keep the lanes apart: a rank-4 request coalesced
    // into an exact batch would corrupt both.
    let mut calls = Vec::new();
    for c in &cols {
        calls.push(Call::apply("svd_16", c.clone()));
        calls.push(Call::apply("svd_16", c.clone()).rank(16));
        calls.push(Call::apply("svd_16", c.clone()).rank(4));
        calls.push(Call::apply("rect_32x16", c.clone()).rank(4));
    }
    let rs = client.call_many(calls).unwrap();
    assert!(rs.iter().all(|r| r.ok), "{:?}", rs.iter().find(|r| !r.ok));
    for (i, _) in cols.iter().enumerate() {
        let base = &rs[4 * i].column;
        // The exact lane is unaffected by concurrent truncated traffic.
        assert_close(base, &exact[i], 1e-6, 1e-6).unwrap();
        // Full-rank truncation reproduces the exact operator.
        assert_close(&rs[4 * i + 1].column, base, 1e-2, 1e-2).unwrap();
        // rank-4 lanes produce well-formed columns of the exact widths.
        assert_eq!(rs[4 * i + 2].column.len(), 16);
        assert!(rs[4 * i + 2].column.iter().all(|v| v.is_finite()));
        assert_eq!(rs[4 * i + 3].column.len(), 32);
        assert!(rs[4 * i + 3].column.iter().all(|v| v.is_finite()));
    }
    // Cache accounting: exactly one build per distinct (model, rank) —
    // (svd_16, 16), (svd_16, 4), (rect_32x16, 4) — and, since 12
    // requests per lane cannot fit a max_batch of 8, at least one
    // follow-up batch per lane hit the cache.
    let stats = client.admin("stats").unwrap();
    let j = fasth::util::json::Json::parse(&stats).unwrap();
    assert_eq!(j.get("lowrank_cache_misses").as_usize(), Some(3), "{stats}");
    assert!(j.get("lowrank_cache_hits").as_usize().unwrap() >= 3, "{stats}");
    // Bad ranks surface as per-request errors, not connection faults.
    let bad = client
        .call(Call::apply("svd_16", cols[0].clone()).rank(17))
        .unwrap();
    assert!(!bad.ok);
    assert!(bad.error.unwrap().contains("rank"));
    let bad_op = client
        .call(Call::expm("svd_16", cols[0].clone()).rank(4))
        .unwrap();
    assert!(!bad_op.ok);
    assert!(bad_op.error.unwrap().contains("rank"));
    server.stop();
}

#[test]
fn pjrt_engine_serves_if_artifacts_present() {
    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts");
        return;
    }
    let engine = fasth::runtime::ArtifactEngine::open(dir).expect("open");
    if !engine.backend_available() {
        eprintln!("SKIP: PJRT execution backend not compiled into this build");
        return;
    }
    let d = *engine.manifest().sizes().first().unwrap();
    let registry = Arc::new(ModelRegistry::new());
    registry.create(&format!("svd_{d}"), d, ExecEngine::Pjrt(Arc::new(engine)), 0xE2F);
    let config = ServerConfig::builder()
        .shards(2)
        .workers(2)
        .max_batch(32)
        .max_wait(Duration::from_millis(2))
        .max_queue_depth(1000)
        .build()
        .unwrap();
    let server = Server::start(config, registry.clone()).unwrap();
    let mut client = Client::connect(&server.local_addr).unwrap();
    let mut rng = Rng::new(4);
    let col: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
    let fwd = client.call(Call::apply(format!("svd_{d}"), col.clone())).unwrap();
    assert!(fwd.ok, "{:?}", fwd.error);
    let back = client.call(Call::inverse(format!("svd_{d}"), fwd.column)).unwrap();
    assert!(back.ok);
    assert_close(&back.column, &col, 2e-2, 2e-2).unwrap();
    // Cross-check against native execution of the same registered weight.
    let model = registry.get(&format!("svd_{d}")).unwrap();
    let param = model.square().expect("square model");
    let mut x = fasth::linalg::Mat::zeros(d, 1);
    for i in 0..d {
        x[(i, 0)] = col[i];
    }
    let native = param.apply(&x, 32);
    let mut client2 = Client::connect(&server.local_addr).unwrap();
    let served = client2.call(Call::apply(format!("svd_{d}"), col)).unwrap();
    assert_close(&served.column, &native.col(0), 1e-2, 1e-2).unwrap();
    server.stop();
}

#[test]
fn malformed_line_gets_error_response() {
    use std::io::{BufRead, BufReader, Write};
    let server = native_server(8, 4);
    let mut stream = std::net::TcpStream::connect(server.local_addr).unwrap();
    writeln!(stream, "this is not json").unwrap();
    stream.flush().unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let resp = fasth::coordinator::Response::from_json(line.trim()).unwrap();
    assert!(!resp.ok);
    assert!(resp.error.unwrap().contains("bad request"));
    server.stop();
}
