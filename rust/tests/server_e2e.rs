//! Coordinator end-to-end over real TCP: batching semantics, response
//! conservation under concurrency, PJRT-backed serving when artifacts
//! exist, and backpressure.

use fasth::coordinator::{
    BatcherConfig, Client, ExecEngine, ModelRegistry, OpKind, Server, ServerConfig,
};
use fasth::util::prop::assert_close;
use fasth::util::Rng;
use std::sync::Arc;
use std::time::Duration;

fn native_server(d: usize, max_batch: usize) -> Server {
    let registry = Arc::new(ModelRegistry::new());
    registry.create(&format!("svd_{d}"), d, ExecEngine::Native { k: 8 }, 0xE2E);
    Server::start(
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            workers: 2,
            batcher: BatcherConfig { max_batch, max_wait: Duration::from_millis(2) },
            max_queue_depth: 10_000,
        },
        registry,
    )
    .expect("start server")
}

#[test]
fn apply_inverse_roundtrip_over_tcp() {
    let server = native_server(16, 8);
    let mut client = Client::connect(&server.local_addr).unwrap();
    let mut rng = Rng::new(1);
    for _ in 0..5 {
        let col: Vec<f32> = (0..16).map(|_| rng.normal_f32()).collect();
        let fwd = client.call("svd_16", OpKind::Apply, col.clone()).unwrap();
        assert!(fwd.ok);
        let back = client.call("svd_16", OpKind::Inverse, fwd.column).unwrap();
        assert!(back.ok);
        assert_close(&back.column, &col, 1e-2, 1e-2).unwrap();
    }
    server.stop();
}

#[test]
fn burst_gets_coalesced_into_batches() {
    let server = native_server(16, 16);
    let mut client = Client::connect(&server.local_addr).unwrap();
    let mut rng = Rng::new(2);
    let cols: Vec<Vec<f32>> =
        (0..64).map(|_| (0..16).map(|_| rng.normal_f32()).collect()).collect();
    let responses = client.call_many("svd_16", OpKind::Apply, cols).unwrap();
    assert_eq!(responses.len(), 64);
    assert!(responses.iter().all(|r| r.ok));
    let max_batch = responses.iter().map(|r| r.batch_size).max().unwrap();
    assert!(max_batch >= 8, "expected real batching, max batch {max_batch}");
    server.stop();
}

#[test]
fn conservation_under_concurrent_clients() {
    let server = native_server(12, 8);
    let addr = server.local_addr;
    let n_clients = 6;
    let per_client = 40;
    let handles: Vec<_> = (0..n_clients)
        .map(|c| {
            std::thread::spawn(move || {
                let mut rng = Rng::new(100 + c);
                let mut client = Client::connect(&addr).unwrap();
                let cols: Vec<Vec<f32>> = (0..per_client)
                    .map(|_| (0..12).map(|_| rng.normal_f32()).collect())
                    .collect();
                let rs = client.call_many("svd_12", OpKind::Apply, cols).unwrap();
                assert_eq!(rs.len(), per_client);
                rs.iter().filter(|r| r.ok).count()
            })
        })
        .collect();
    let total_ok: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    assert_eq!(total_ok, n_clients as usize * per_client);
    // Server-side accounting agrees.
    let mut admin = Client::connect(&addr).unwrap();
    let stats = admin.admin("stats").unwrap();
    let j = fasth::util::json::Json::parse(&stats).unwrap();
    assert_eq!(
        j.get("responses_ok").as_usize(),
        Some(n_clients as usize * per_client)
    );
    server.stop();
}

#[test]
fn expm_cayley_ops_served() {
    let server = native_server(12, 4);
    let mut client = Client::connect(&server.local_addr).unwrap();
    let mut rng = Rng::new(3);
    let col: Vec<f32> = (0..12).map(|_| rng.normal_f32()).collect();
    for op in [OpKind::Expm, OpKind::Cayley] {
        let r = client.call("svd_12", op, col.clone()).unwrap();
        assert!(r.ok, "{op:?} failed: {:?}", r.error);
        assert_eq!(r.column.len(), 12);
        assert!(r.column.iter().all(|v| v.is_finite()));
    }
    server.stop();
}

#[test]
fn pjrt_engine_serves_if_artifacts_present() {
    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts");
        return;
    }
    let engine = fasth::runtime::ArtifactEngine::open(dir).expect("open");
    if !engine.backend_available() {
        eprintln!("SKIP: PJRT execution backend not compiled into this build");
        return;
    }
    let d = *engine.manifest().sizes().first().unwrap();
    let registry = Arc::new(ModelRegistry::new());
    registry.create(&format!("svd_{d}"), d, ExecEngine::Pjrt(Arc::new(engine)), 0xE2F);
    let server = Server::start(
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            workers: 2,
            batcher: BatcherConfig { max_batch: 32, max_wait: Duration::from_millis(2) },
            max_queue_depth: 1000,
        },
        registry.clone(),
    )
    .unwrap();
    let mut client = Client::connect(&server.local_addr).unwrap();
    let mut rng = Rng::new(4);
    let col: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
    let fwd = client.call(&format!("svd_{d}"), OpKind::Apply, col.clone()).unwrap();
    assert!(fwd.ok, "{:?}", fwd.error);
    let back = client.call(&format!("svd_{d}"), OpKind::Inverse, fwd.column).unwrap();
    assert!(back.ok);
    assert_close(&back.column, &col, 2e-2, 2e-2).unwrap();
    // Cross-check against native execution of the same registered weight.
    let model = registry.get(&format!("svd_{d}")).unwrap();
    let mut x = fasth::linalg::Mat::zeros(d, 1);
    for i in 0..d {
        x[(i, 0)] = col[i];
    }
    let native = model.param.apply(&x, 32);
    let mut client2 = Client::connect(&server.local_addr).unwrap();
    let served = client2.call(&format!("svd_{d}"), OpKind::Apply, col).unwrap();
    assert_close(&served.column, &native.col(0), 1e-2, 1e-2).unwrap();
    server.stop();
}

#[test]
fn malformed_line_gets_error_response() {
    use std::io::{BufRead, BufReader, Write};
    let server = native_server(8, 4);
    let mut stream = std::net::TcpStream::connect(server.local_addr).unwrap();
    writeln!(stream, "this is not json").unwrap();
    stream.flush().unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let resp = fasth::coordinator::Response::from_json(line.trim()).unwrap();
    assert!(!resp.ok);
    assert!(resp.error.unwrap().contains("bad request"));
    server.stop();
}
