//! Property suite for the approximate-SVD subsystem: Eckart–Young error
//! bounds for the randomized range-finder, power-method convergence and
//! deflation orthogonality, and oracle equivalence of the `LowRank`
//! kernels against `linalg::oracle` / one-sided Jacobi.
//!
//! Seeded through `util::prop::check`, so the nightly fuzz lane can
//! resweep the sketch matrices `Ω` with `FASTH_PROP_SEED=$(date ...)`.

use fasth::linalg::{matmul, matmul_nt, matmul_tn, oracle, Mat};
use fasth::svd::approx::{power_svd, randomized_svd, refine, thin_qr, PowerConfig, SketchConfig};
use fasth::svd::jacobi;
use fasth::util::prop::{assert_close, check};
use fasth::util::Rng;

/// Build an `m×n` matrix with the exact spectrum `sigma` (descending):
/// `A = Q_u·diag(σ)·Q_vᵀ` with Haar-ish orthonormal factors from the QR
/// of Gaussian blocks. Ground truth for every Eckart–Young assertion.
fn known_spectrum(m: usize, n: usize, sigma: &[f32], rng: &mut Rng) -> Mat {
    let k = m.min(n);
    assert_eq!(sigma.len(), k);
    let (qu, _) = thin_qr(&Mat::randn(m, k, rng));
    let (qv, _) = thin_qr(&Mat::randn(n, k, rng));
    matmul_nt(&matmul(&qu, &Mat::diag(sigma)), &qv)
}

/// Geometric spectrum σ_i = ratio^i — the graded case where truncation
/// is meaningful and power iterations converge linearly in the gap.
fn graded(k: usize, ratio: f32) -> Vec<f32> {
    (0..k).map(|i| ratio.powi(i as i32)).collect()
}

/// Frobenius Eckart–Young optimum for truncation at `r`:
/// `min_{rank≤r} ‖A − B‖_F = sqrt(Σ_{i>r} σ_i²)`.
fn tail_fro(sigma: &[f32], r: usize) -> f32 {
    sigma[r..].iter().map(|s| s * s).sum::<f32>().sqrt()
}

#[test]
fn sketch_respects_eckart_young_frobenius() {
    check("sketch_eckart_young_fro", 16, |rng| {
        let m = 8 + (rng.next_u64() % 25) as usize;
        let n = 8 + (rng.next_u64() % 25) as usize;
        let k = m.min(n);
        let sigma = graded(k, 0.8);
        let a = known_spectrum(m, n, &sigma, rng);
        let r = 1 + (rng.next_u64() as usize % (k - 1));
        let lr = randomized_svd(&a, r, &SketchConfig::default(), rng);
        let err = a.sub(&lr.materialize()).fro_norm();
        let opt = tail_fro(&sigma, r);
        // The sketch is not the optimal rank-r approximant, but with
        // p=8 oversampling and q=2 power iterations it sits within a
        // small constant of the Eckart–Young floor.
        if err > 1.5 * opt + 1e-4 {
            return Err(format!(
                "m={m} n={n} r={r}: ‖A−A_r‖_F = {err:.4e} > 1.5·σ-tail = {:.4e}",
                1.5 * opt
            ));
        }
        Ok(())
    });
}

#[test]
fn sketch_spectral_error_bounded_by_sigma_next() {
    check("sketch_eckart_young_spectral", 12, |rng| {
        let d = 12 + (rng.next_u64() % 21) as usize;
        let sigma = graded(d, 0.75);
        let a = known_spectrum(d, d, &sigma, rng);
        let r = 2 + (rng.next_u64() as usize % (d / 2));
        let lr = randomized_svd(&a, r, &SketchConfig::default(), rng);
        // ‖A − A_r‖₂ via a rank-1 power pass on the dense residual; the
        // spectral Eckart–Young floor is σ_{r+1} exactly.
        let resid = a.sub(&lr.materialize());
        let top = power_svd(&resid, 1, &PowerConfig::default(), rng);
        let err2 = top.sigma[0];
        let floor = sigma[r];
        if err2 > 2.0 * floor + 1e-4 {
            return Err(format!(
                "d={d} r={r}: ‖A−A_r‖₂ ≈ {err2:.4e} > 2·σ_{{r+1}} = {:.4e}",
                2.0 * floor
            ));
        }
        Ok(())
    });
}

#[test]
fn full_rank_sketch_matches_jacobi_oracle() {
    check("sketch_vs_jacobi", 12, |rng| {
        let d = 6 + (rng.next_u64() % 15) as usize;
        let a = Mat::randn(d, d, rng);
        let lr = randomized_svd(&a, d, &SketchConfig::default(), rng);
        let exact = jacobi::svd(&a);
        // Full-rank sketch spans the whole space, so the spectra agree
        // to f32 working precision regardless of the random Ω.
        assert_close(&lr.sigma, &exact.sigma, 1e-3, 1e-3)?;
        let recon = lr.materialize();
        assert_close(recon.data(), a.data(), 1e-3, 1e-3)
    });
}

#[test]
fn lowrank_kernels_match_oracle_matmul() {
    check("lowrank_vs_oracle", 16, |rng| {
        let m = 8 + (rng.next_u64() % 17) as usize;
        let n = 8 + (rng.next_u64() % 17) as usize;
        let k = m.min(n);
        let a = known_spectrum(m, n, &graded(k, 0.7), rng);
        let r = 1 + (rng.next_u64() as usize % k);
        let lr = randomized_svd(&a, r, &SketchConfig::default(), rng);
        let dense = lr.materialize();
        let x = Mat::randn(n, 3, rng);
        // apply ≡ the f64 oracle product with the materialized A_r.
        let fast = lr.apply(&x);
        let slow = oracle::matmul_f64(&dense, &x);
        assert_close(fast.data(), slow.data(), 1e-4, 1e-3)?;
        // pinv ≡ V·Σ⁻¹·Uᵀ against the oracle, computed factor-wise.
        let y = Mat::randn(m, 3, rng);
        let fast_p = lr.pinv(&y);
        let uty = oracle::matmul_f64(&lr.u.t(), &y);
        let inv_sigma: Vec<f32> = lr.sigma.iter().map(|s| 1.0 / s).collect();
        let slow_p = oracle::matmul_f64(&lr.v, &oracle::matmul_f64(&Mat::diag(&inv_sigma), &uty));
        assert_close(fast_p.data(), slow_p.data(), 1e-3, 1e-2)
    });
}

#[test]
fn well_conditioned_pinv_inverts_like_oracle() {
    check("pinv_vs_oracle_inverse", 12, |rng| {
        let d = 6 + (rng.next_u64() % 11) as usize;
        // Condition number ≤ 3: spectrum in [0.5, 1.5].
        let sigma: Vec<f32> = (0..d).map(|i| 1.5 - i as f32 / (d as f32 - 1.0)).collect();
        let a = known_spectrum(d, d, &sigma, rng);
        let lr = randomized_svd(&a, d, &SketchConfig::default(), rng);
        let y = Mat::randn(d, 2, rng);
        let x_lr = lr.pinv(&y);
        let inv = oracle::inverse_f64(&a).ok_or("oracle found A singular")?;
        let x_oracle = oracle::matmul_f64(&inv, &y);
        assert_close(x_lr.data(), x_oracle.data(), 1e-2, 1e-2)
    });
}

#[test]
fn power_method_converges_on_graded_spectra() {
    check("power_convergence", 12, |rng| {
        let d = 10 + (rng.next_u64() % 15) as usize;
        let sigma = graded(d, 0.6);
        let a = known_spectrum(d, d, &sigma, rng);
        let lr = power_svd(&a, 4, &PowerConfig::default(), rng);
        assert_close(&lr.sigma, &sigma[..4], 1e-2, 1e-2)
    });
}

#[test]
fn deflation_keeps_factors_orthonormal() {
    check("deflation_orthogonality", 12, |rng| {
        let m = 12 + (rng.next_u64() % 13) as usize;
        let n = 9 + (rng.next_u64() % 13) as usize;
        let k = m.min(n);
        let a = known_spectrum(m, n, &graded(k, 0.7), rng);
        let r = 3 + (rng.next_u64() as usize % 4);
        for lr in [
            power_svd(&a, r, &PowerConfig::default(), rng),
            randomized_svd(&a, r, &SketchConfig::default(), rng),
        ] {
            let du = matmul_tn(&lr.u, &lr.u).defect_from_identity();
            let dv = matmul_tn(&lr.v, &lr.v).defect_from_identity();
            if du > 1e-3 || dv > 1e-3 {
                return Err(format!("orthogonality defect UᵀU={du:.2e} VᵀV={dv:.2e}"));
            }
            // Deflation must also order the spectrum descending.
            if lr.sigma.windows(2).any(|w| w[0] < w[1] - 1e-5) {
                return Err(format!("σ not descending: {:?}", lr.sigma));
            }
        }
        Ok(())
    });
}

#[test]
fn refine_never_degrades_a_coarse_sketch() {
    check("refine_polish", 8, |rng| {
        let d = 14 + (rng.next_u64() % 11) as usize;
        let sigma = graded(d, 0.7);
        let a = known_spectrum(d, d, &sigma, rng);
        // Deliberately coarse: no power iterations, minimal oversampling.
        let coarse_cfg = SketchConfig { oversample: 2, power_iters: 0 };
        let coarse = randomized_svd(&a, 4, &coarse_cfg, rng);
        let polished = refine(&a, &coarse, &PowerConfig::default(), rng);
        let err_coarse = a.sub(&coarse.materialize()).fro_norm();
        let err_polished = a.sub(&polished.materialize()).fro_norm();
        if err_polished > err_coarse + 1e-3 {
            return Err(format!(
                "refine worsened the sketch: {err_coarse:.4e} → {err_polished:.4e}"
            ));
        }
        // And the polished spectrum should sit near the truth.
        assert_close(&polished.sigma, &sigma[..4], 2e-2, 2e-2)
    });
}

#[test]
fn truncate_nests_like_the_spectrum() {
    check("truncate_nesting", 8, |rng| {
        let d = 16 + (rng.next_u64() % 9) as usize;
        let sigma = graded(d, 0.8);
        let a = known_spectrum(d, d, &sigma, rng);
        let lr8 = randomized_svd(&a, 8, &SketchConfig::default(), rng);
        let lr4 = lr8.truncate(4);
        // Truncating a rank-8 factorization to 4 keeps the leading
        // triplets verbatim — same σ prefix, monotonically larger error.
        assert_close(&lr4.sigma, &lr8.sigma[..4], 0.0, 0.0)?;
        let e8 = a.sub(&lr8.materialize()).fro_norm();
        let e4 = a.sub(&lr4.materialize()).fro_norm();
        if e4 + 1e-5 < e8 {
            return Err(format!("rank-4 error {e4:.4e} below rank-8 error {e8:.4e}"));
        }
        Ok(())
    });
}
