//! Edge coverage for the packed-panel register-tiled GEMM microkernel:
//! the skinny→packed register-path boundary (n = 63/64/65), K panels
//! straddling `kc` (255/256/257), MR/NR ragged tails, threaded-vs-serial
//! agreement on the rerouted TN/NT paths, TT honoring the receiver's
//! config, and a packed-panel-vs-f64-oracle property sweep over all four
//! transpose combinations with random `alpha`/`beta`.
//!
//! Since §Perf iteration 9 this also holds the kernel-dispatch oracle
//! suite: the AVX2+FMA microkernel forced against the scalar kernel (the
//! portable fallback doubles as the property oracle) across ragged
//! edges, transposes, and random `alpha`/`beta`, plus the tall-skinny
//! column-parallel split checked bit-identical against the serial
//! driver. SIMD-only assertions self-skip on machines without AVX2/FMA,
//! so the suite passes on any x86_64 *and* non-x86 runner.

use fasth::linalg::gemm::{matmul, matmul_nt, matmul_tn, Gemm, KernelChoice, Trans};
use fasth::linalg::{oracle, simd, Mat};
use fasth::util::prop::{assert_close, check};
use fasth::util::Rng;

fn serial() -> Gemm {
    Gemm { par_flop_threshold: usize::MAX, ..Default::default() }
}

/// A config that pins the microkernel regardless of CPU detection or the
/// `FASTH_FORCE_SCALAR` override.
fn forced(kernel: KernelChoice) -> Gemm {
    Gemm { kernel: Some(kernel), ..Default::default() }
}

fn run_gemm(g: &Gemm, alpha: f32, a: &Mat, ta: Trans, b: &Mat, tb: Trans, beta: f32) -> Mat {
    let (m, n) = match (ta, tb) {
        (Trans::No, Trans::No) => (a.rows(), b.cols()),
        (Trans::Yes, Trans::No) => (a.cols(), b.cols()),
        (Trans::No, Trans::Yes) => (a.rows(), b.rows()),
        (Trans::Yes, Trans::Yes) => (a.cols(), b.rows()),
    };
    let mut c = Mat::zeros(m, n);
    g.gemm(alpha, a, ta, b, tb, beta, &mut c);
    c
}

/// `alpha·op(A)·op(B) + beta·C₀` through the f64 oracle.
fn reference(alpha: f32, a: &Mat, ta: Trans, b: &Mat, tb: Trans, beta: f32, c0: &Mat) -> Mat {
    let am = if ta == Trans::Yes { a.t() } else { a.clone() };
    let bm = if tb == Trans::Yes { b.t() } else { b.clone() };
    let mut out = oracle::matmul_f64(&am, &bm).scale(alpha);
    out.axpy(beta, c0);
    out
}

#[test]
fn register_path_boundary_n_63_64_65() {
    // n ≤ 64 takes the stack-accumulated skinny kernel, n > 64 the packed
    // microkernel; both sides of the boundary must match the oracle.
    let mut rng = Rng::new(0xB0);
    for n in [63usize, 64, 65] {
        let a = Mat::randn(50, 77, &mut rng);
        let b = Mat::randn(77, n, &mut rng);
        let got = matmul(&a, &b);
        let want = oracle::matmul_f64(&a, &b);
        assert_close(got.data(), want.data(), 1e-3, 1e-3)
            .unwrap_or_else(|e| panic!("n={n}: {e}"));
    }
}

#[test]
fn kc_panel_straddling() {
    // K one below / exactly at / one above the default kc = 256 panel
    // depth, on the packed path (n > 64), threaded and serial.
    let mut rng = Rng::new(0xB1);
    for k in [255usize, 256, 257] {
        let a = Mat::randn(24, k, &mut rng);
        let b = Mat::randn(k, 96, &mut rng);
        let want = oracle::matmul_f64(&a, &b);
        let threaded = matmul(&a, &b);
        assert_close(threaded.data(), want.data(), 2e-3, 2e-3)
            .unwrap_or_else(|e| panic!("k={k} threaded: {e}"));
        let ser = run_gemm(&serial(), 1.0, &a, Trans::No, &b, Trans::No, 0.0);
        assert_close(ser.data(), want.data(), 2e-3, 2e-3)
            .unwrap_or_else(|e| panic!("k={k} serial: {e}"));
        // Row-slab threading must not change each row's summation order.
        assert_close(threaded.data(), ser.data(), 1e-6, 1e-6)
            .unwrap_or_else(|e| panic!("k={k} threaded vs serial: {e}"));
    }
}

#[test]
fn mr_nr_ragged_tails() {
    // Row counts around the MR = 8 tile height and widths around NR = 8
    // panel multiples (all > 64 so the packed path is taken).
    let mut rng = Rng::new(0xB2);
    for &m in &[1usize, 5, 7, 8, 9, 15, 16, 17] {
        for &n in &[65usize, 71, 72, 73, 80, 81] {
            let k = 40;
            let a = Mat::randn(m, k, &mut rng);
            let b = Mat::randn(k, n, &mut rng);
            let got = matmul(&a, &b);
            let want = oracle::matmul_f64(&a, &b);
            assert_close(got.data(), want.data(), 1e-3, 1e-3)
                .unwrap_or_else(|e| panic!("m={m} n={n}: {e}"));
        }
    }
}

#[test]
fn tn_threaded_vs_serial_large_output() {
    // Large TN outputs route through the packed kernel (packing A
    // straight from K×M storage, no a.t() materialization).
    let mut rng = Rng::new(0xB3);
    let a = Mat::randn(600, 150, &mut rng); // K×M
    let b = Mat::randn(600, 140, &mut rng); // K×N
    let want = oracle::matmul_f64(&a.t(), &b);
    let threaded = matmul_tn(&a, &b);
    assert_close(threaded.data(), want.data(), 2e-3, 2e-3).unwrap();
    let ser = run_gemm(&serial(), 1.0, &a, Trans::Yes, &b, Trans::No, 0.0);
    assert_close(ser.data(), want.data(), 2e-3, 2e-3).unwrap();
    assert_close(threaded.data(), ser.data(), 1e-6, 1e-6).unwrap();
}

#[test]
fn tn_threaded_vs_serial_small_output() {
    // FastH's YᵀA shape: tiny output, long K reduction (dedicated kernel;
    // the parallel reduction reorders sums, so agreement is approximate).
    let mut rng = Rng::new(0xB4);
    let a = Mat::randn(4000, 32, &mut rng);
    let b = Mat::randn(4000, 32, &mut rng);
    let threaded = matmul_tn(&a, &b);
    let ser = run_gemm(&serial(), 1.0, &a, Trans::Yes, &b, Trans::No, 0.0);
    assert_close(threaded.data(), ser.data(), 1e-3, 1e-3).unwrap();
    let want = oracle::matmul_f64(&a.t(), &b);
    assert_close(threaded.data(), want.data(), 5e-3, 5e-3).unwrap();
}

#[test]
fn nt_threaded_vs_serial_large_output() {
    let mut rng = Rng::new(0xB5);
    let a = Mat::randn(150, 90, &mut rng); // M×K
    let b = Mat::randn(145, 90, &mut rng); // N×K
    let want = oracle::matmul_f64(&a, &b.t());
    let threaded = matmul_nt(&a, &b);
    assert_close(threaded.data(), want.data(), 2e-3, 2e-3).unwrap();
    let ser = run_gemm(&serial(), 1.0, &a, Trans::No, &b, Trans::Yes, 0.0);
    assert_close(ser.data(), want.data(), 2e-3, 2e-3).unwrap();
    assert_close(threaded.data(), ser.data(), 1e-6, 1e-6).unwrap();
}

#[test]
fn tt_respects_gemm_config() {
    // TT used to route through `matmul`'s default config; it must now
    // honor the receiver — including deliberately odd kc/nc blockings.
    let mut rng = Rng::new(0xB6);
    let a = Mat::randn(30, 70, &mut rng); // stored K×M → C = AᵀBᵀ is 70×90
    let b = Mat::randn(90, 30, &mut rng); // stored N×K
    let want = oracle::matmul_f64(&a.t(), &b.t());
    for cfg in [
        serial(),
        Gemm { kc: 16, nc: 24, mr_chunk: 8, ..serial() },
        Gemm { kc: 7, nc: 13, mr_chunk: 8, par_flop_threshold: 0, ..Default::default() },
    ] {
        let got = run_gemm(&cfg, 1.0, &a, Trans::Yes, &b, Trans::Yes, 0.0);
        assert_close(got.data(), want.data(), 1e-3, 1e-3)
            .unwrap_or_else(|e| panic!("kc={} nc={}: {e}", cfg.kc, cfg.nc));
    }
}

#[test]
fn packed_vs_oracle_property_sweep() {
    check("gemm_packed_sweep", 24, |rng| {
        let m = 1 + rng.below(120);
        let k = 1 + rng.below(160);
        let n = 65 + rng.below(90); // force the packed path on NN
        let alpha = rng.normal_f32();
        let beta = if rng.below(2) == 0 { 0.0 } else { rng.normal_f32() };
        let (ta, tb) = match rng.below(4) {
            0 => (Trans::No, Trans::No),
            1 => (Trans::Yes, Trans::No),
            2 => (Trans::No, Trans::Yes),
            _ => (Trans::Yes, Trans::Yes),
        };
        let a = match ta {
            Trans::No => Mat::randn(m, k, rng),
            Trans::Yes => Mat::randn(k, m, rng),
        };
        let b = match tb {
            Trans::No => Mat::randn(k, n, rng),
            Trans::Yes => Mat::randn(n, k, rng),
        };
        let c0 = Mat::randn(m, n, rng);
        let mut got = c0.clone();
        Gemm::default().gemm(alpha, &a, ta, &b, tb, beta, &mut got);
        let want = reference(alpha, &a, ta, &b, tb, beta, &c0);
        assert_close(got.data(), want.data(), 5e-3, 5e-3)
    });
}

#[test]
fn wide_output_parallel_b_pack_threaded_vs_serial() {
    // Very wide outputs (n > nc = 512) fan the B-panel pack out across
    // the pool; narrow outputs keep the serial pack. Either way the
    // packed panels are byte-identical, so threaded and serial results
    // must agree to f32 reproducibility — across NN, TN, NT and a
    // two-window (n > 2·nc) sweep.
    let mut rng = Rng::new(0xB7);
    let cases: [(Trans, Trans, usize, usize, usize); 4] = [
        (Trans::No, Trans::No, 48, 70, 600),
        (Trans::Yes, Trans::No, 40, 90, 520),
        (Trans::No, Trans::Yes, 150, 40, 640),
        (Trans::No, Trans::No, 33, 50, 1100), // two full B windows
    ];
    for (ta, tb, m, k, n) in cases {
        let a = match ta {
            Trans::No => Mat::randn(m, k, &mut rng),
            Trans::Yes => Mat::randn(k, m, &mut rng),
        };
        let b = match tb {
            Trans::No => Mat::randn(k, n, &mut rng),
            Trans::Yes => Mat::randn(n, k, &mut rng),
        };
        let threaded = run_gemm(&Gemm::default(), 1.0, &a, ta, &b, tb, 0.0);
        let ser = run_gemm(&serial(), 1.0, &a, ta, &b, tb, 0.0);
        assert_close(threaded.data(), ser.data(), 1e-7, 1e-7)
            .unwrap_or_else(|e| panic!("{ta:?}/{tb:?} m={m} k={k} n={n}: {e}"));
        let want = reference(1.0, &a, ta, &b, tb, 0.0, &Mat::zeros(m, n));
        assert_close(threaded.data(), want.data(), 5e-3, 5e-3)
            .unwrap_or_else(|e| panic!("{ta:?}/{tb:?} vs oracle m={m} k={k} n={n}: {e}"));
    }
}

#[test]
fn simd_vs_scalar_ragged_edges() {
    // Forced SIMD against the forced scalar oracle on the routing and
    // panel edges: n straddling the skinny→packed boundary (63/64/65),
    // plus ragged NR widths and K straddling kc (255/256/257). Both
    // kernels walk the same packed panels in the same kk order; the only
    // divergence is FMA's single rounding per multiply-add, so the
    // tolerance is a few hundred ULPs — far below the f64-oracle gate.
    let mut rng = Rng::new(0xC0);
    let scalar = forced(KernelChoice::Scalar);
    let simd_g = forced(KernelChoice::Simd);
    for &n in &[63usize, 64, 65, 71, 73, 255, 256, 257] {
        for &k in &[255usize, 256, 257] {
            let m = 9; // one full MR tile plus a 1-row ragged tail
            let a = Mat::randn(m, k, &mut rng);
            let b = Mat::randn(k, n, &mut rng);
            let want = oracle::matmul_f64(&a, &b);
            let s = run_gemm(&scalar, 1.0, &a, Trans::No, &b, Trans::No, 0.0);
            assert_close(s.data(), want.data(), 2e-3, 2e-3)
                .unwrap_or_else(|e| panic!("scalar n={n} k={k}: {e}"));
            if simd::simd_available() {
                let v = run_gemm(&simd_g, 1.0, &a, Trans::No, &b, Trans::No, 0.0);
                assert_close(v.data(), want.data(), 2e-3, 2e-3)
                    .unwrap_or_else(|e| panic!("simd n={n} k={k}: {e}"));
                assert_close(v.data(), s.data(), 1e-4, 5e-5)
                    .unwrap_or_else(|e| panic!("simd vs scalar n={n} k={k}: {e}"));
            }
        }
    }
}

#[test]
fn simd_vs_scalar_property_sweep() {
    // Random α/β and all four transpose combinations through both forced
    // kernels: the scalar microkernel is the property oracle for the
    // AVX2 path, and both must stay inside the f64-oracle gate.
    if !simd::simd_available() {
        eprintln!("simd_vs_scalar_property_sweep: no AVX2+FMA on this host, skipping");
        return;
    }
    check("gemm_simd_vs_scalar", 24, |rng| {
        let m = 1 + rng.below(48);
        let k = 1 + rng.below(300);
        let n = 65 + rng.below(200); // force the packed path
        let alpha = rng.normal_f32();
        let beta = if rng.below(2) == 0 { 0.0 } else { rng.normal_f32() };
        let (ta, tb) = match rng.below(4) {
            0 => (Trans::No, Trans::No),
            1 => (Trans::Yes, Trans::No),
            2 => (Trans::No, Trans::Yes),
            _ => (Trans::Yes, Trans::Yes),
        };
        let a = match ta {
            Trans::No => Mat::randn(m, k, rng),
            Trans::Yes => Mat::randn(k, m, rng),
        };
        let b = match tb {
            Trans::No => Mat::randn(k, n, rng),
            Trans::Yes => Mat::randn(n, k, rng),
        };
        let c0 = Mat::randn(m, n, rng);
        let mut s = c0.clone();
        forced(KernelChoice::Scalar).gemm(alpha, &a, ta, &b, tb, beta, &mut s);
        let mut v = c0.clone();
        forced(KernelChoice::Simd).gemm(alpha, &a, ta, &b, tb, beta, &mut v);
        assert_close(v.data(), s.data(), 1e-4, 5e-5)?;
        let want = reference(alpha, &a, ta, &b, tb, beta, &c0);
        assert_close(v.data(), want.data(), 5e-3, 5e-3)
    });
}

#[test]
fn tall_skinny_column_split_matches_serial_bitwise() {
    // The nc-parallel column split packs the same NR-aligned B panels the
    // serial driver does and accumulates `alpha·(tile)` per k0 window in
    // the same ascending-k0 order into a private buffer, so for β = 0 the
    // threaded result is bit-identical to serial — whichever microkernel
    // the host dispatches (both runs dispatch the same one).
    let mut rng = Rng::new(0xC1);
    let ts_g = forced(KernelChoice::TallSkinny);
    for &(m, k, n) in &[(1usize, 257usize, 1024usize), (4, 300, 520), (8, 64, 96)] {
        let a = Mat::randn(m, k, &mut rng);
        let b = Mat::randn(k, n, &mut rng);
        let ts = run_gemm(&ts_g, 2.0, &a, Trans::No, &b, Trans::No, 0.0);
        let ser = run_gemm(&serial(), 2.0, &a, Trans::No, &b, Trans::No, 0.0);
        assert_eq!(ts.data(), ser.data(), "tall-skinny vs serial m={m} k={k} n={n}");
        let want = reference(2.0, &a, Trans::No, &b, Trans::No, 0.0, &Mat::zeros(m, n));
        assert_close(ts.data(), want.data(), 5e-3, 5e-3)
            .unwrap_or_else(|e| panic!("tall-skinny vs oracle m={m} k={k} n={n}: {e}"));
    }
}
