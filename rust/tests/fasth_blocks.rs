//! Edge-case coverage beneath `engines_equivalence.rs`: the FastH block
//! partition (ragged tails, `k = 1`, `k = d`, `k > n`) observed through
//! the public [`build_blocks`] API, plus an [`Engine`] facade spot-check
//! (`name` strings, and `step` agreeing with `apply` and with the
//! sequential reference on outputs and gradients).

use fasth::householder::fasth::build_blocks;
use fasth::householder::{Engine, HouseholderVectors};
use fasth::linalg::Mat;
use fasth::util::prop::assert_close;
use fasth::util::Rng;

fn widths(d: usize, n: usize, k: usize, seed: u64) -> Vec<usize> {
    let mut rng = Rng::new(seed);
    let hv = HouseholderVectors::random(d, n, &mut rng);
    build_blocks(&hv, k).iter().map(|b| b.width()).collect()
}

#[test]
fn partition_with_ragged_tail() {
    // d = 10 reflections, k = 4: blocks of 4, 4, and a ragged tail of 2.
    assert_eq!(widths(10, 10, 4, 1), vec![4, 4, 2]);
    // d = 192, k = 14 (14 ∤ 192): 13 full blocks + tail of 10.
    let w = widths(192, 192, 14, 2);
    assert_eq!(w.len(), 14);
    assert!(w[..13].iter().all(|&x| x == 14));
    assert_eq!(w[13], 192 - 13 * 14);
    assert_eq!(w.iter().sum::<usize>(), 192);
}

#[test]
fn partition_k_equals_one() {
    // k = 1 degenerates to one reflection per block.
    let w = widths(9, 9, 1, 3);
    assert_eq!(w, vec![1; 9]);
}

#[test]
fn partition_k_equals_d() {
    // k = d is a single full-width block (Algorithm 1 with one P).
    assert_eq!(widths(12, 12, 12, 4), vec![12]);
}

#[test]
fn partition_k_larger_than_n() {
    // Oversized k clamps to the number of reflections.
    assert_eq!(widths(10, 4, 64, 5), vec![4]);
}

#[test]
fn partition_covers_every_reflection_exactly_once() {
    for (n, k) in [(1usize, 1usize), (1, 7), (5, 2), (16, 4), (17, 4), (33, 8)] {
        let w = widths(40, n, k, 0xC0FE ^ (n as u64) ^ ((k as u64) << 8));
        assert_eq!(w.iter().sum::<usize>(), n, "n={n} k={k}");
        assert!(w.iter().all(|&x| (1..=k).contains(&x)), "n={n} k={k} widths {w:?}");
        assert!(w[..w.len() - 1].iter().all(|&x| x == k), "only the tail may be ragged");
    }
}

#[test]
fn engine_names_are_stable() {
    assert_eq!(Engine::Sequential.name(), "sequential");
    assert_eq!(Engine::Parallel.name(), "parallel");
    assert_eq!(Engine::FastH { k: 8 }.name(), "fasth(k=8)");
    assert_eq!(Engine::FastH { k: 1 }.name(), "fasth(k=1)");
}

#[test]
fn engine_step_agrees_with_apply_and_sequential() {
    let mut rng = Rng::new(0xB10C);
    let (d, m) = (24, 5);
    let hv = HouseholderVectors::random_full(d, &mut rng);
    let x = Mat::randn(d, m, &mut rng);
    let g = Mat::randn(d, m, &mut rng);

    let (a_ref, dx_ref, dv_ref) = Engine::Sequential.step(&hv, &x, &g);
    for engine in [
        Engine::Sequential,
        Engine::Parallel,
        Engine::FastH { k: 1 },
        Engine::FastH { k: 5 }, // ragged: 5 ∤ 24
        Engine::FastH { k: 24 },
    ] {
        // step's forward output must equal the engine's own apply…
        let (a, dx, dv) = engine.step(&hv, &x, &g);
        let applied = engine.apply(&hv, &x);
        assert_close(a.data(), applied.data(), 1e-4, 1e-4)
            .unwrap_or_else(|e| panic!("{} step-vs-apply: {e}", engine.name()));
        // …and everything must match the sequential reference.
        assert_close(a.data(), a_ref.data(), 1e-3, 1e-3)
            .unwrap_or_else(|e| panic!("{} fwd: {e}", engine.name()));
        assert_close(dx.data(), dx_ref.data(), 1e-3, 1e-3)
            .unwrap_or_else(|e| panic!("{} dx: {e}", engine.name()));
        assert_close(dv.data(), dv_ref.data(), 3e-3, 3e-3)
            .unwrap_or_else(|e| panic!("{} dv: {e}", engine.name()));
    }
}
