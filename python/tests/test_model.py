"""L2 correctness: custom-VJP FastH vs jax.grad of the reference, and the
SVD-layer ops (Table 1 right column) vs materialized weights."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

jax.config.update("jax_enable_x64", False)


def rand(key, *shape):
    return jax.random.normal(key, shape, dtype=jnp.float32)


def keys(seed, n):
    return jax.random.split(jax.random.PRNGKey(seed), n)


class TestFasthApply:
    @pytest.mark.parametrize("d,k,m", [(12, 3, 4), (16, 4, 2), (20, 5, 7), (8, 8, 3)])
    def test_forward_matches_ref(self, d, k, m):
        k1, k2 = keys(10, 2)
        v = rand(k1, d, d)
        x = rand(k2, d, m)
        got = model.fasth_apply(v, x, k)
        np.testing.assert_allclose(got, ref.seq_apply(v, x), rtol=1e-3, atol=1e-3)

    def test_transpose_forward(self):
        k1, k2 = keys(11, 2)
        d, k, m = 12, 4, 3
        v = rand(k1, d, d)
        x = rand(k2, d, m)
        got = model.fasth_apply_transpose(v, x, k)
        np.testing.assert_allclose(got, ref.seq_apply_transpose(v, x), rtol=1e-3, atol=1e-3)

    @pytest.mark.parametrize("d,k,m", [(9, 3, 2), (12, 4, 3), (8, 2, 5)])
    def test_custom_vjp_matches_autodiff_of_ref(self, d, k, m):
        """The central check: Algorithm 2's hand-written backward must
        equal jax.grad through the definitional reference."""
        k1, k2, k3 = keys(12, 3)
        v = rand(k1, d, d)
        x = rand(k2, d, m)
        g = rand(k3, d, m)

        dv, dx = jax.grad(lambda vv, xx: ref.loss_dot(model.fasth_apply(vv, xx, k), g),
                          argnums=(0, 1))(v, x)
        dv_ref, dx_ref = jax.grad(lambda vv, xx: ref.loss_dot(ref.seq_apply(vv, xx), g),
                                  argnums=(0, 1))(v, x)
        np.testing.assert_allclose(dx, dx_ref, rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(dv, dv_ref, rtol=2e-3, atol=2e-3)

    @settings(max_examples=10, deadline=None)
    @given(nb=st.integers(1, 4), k=st.integers(1, 5), m=st.integers(1, 4),
           seed=st.integers(0, 2**16))
    def test_hypothesis_grad_sweep(self, nb, k, m, seed):
        d = max(nb * k, 2)
        k1, k2, k3 = keys(seed, 3)
        v = rand(k1, d, nb * k)
        x = rand(k2, d, m)
        g = rand(k3, d, m)
        dv, dx = jax.grad(lambda vv, xx: ref.loss_dot(model.fasth_apply(vv, xx, k), g),
                          argnums=(0, 1))(v, x)
        dv_ref, dx_ref = jax.grad(lambda vv, xx: ref.loss_dot(ref.seq_apply(vv, xx), g),
                                  argnums=(0, 1))(v, x)
        np.testing.assert_allclose(dx, dx_ref, rtol=5e-3, atol=5e-3)
        np.testing.assert_allclose(dv, dv_ref, rtol=5e-3, atol=5e-3)

    def test_jit_compiles_and_matches(self):
        d, k, m = 16, 4, 3
        k1, k2 = keys(13, 2)
        v, x = rand(k1, d, d), rand(k2, d, m)
        eager = model.fasth_apply(v, x, k)
        jitted = jax.jit(lambda vv, xx: model.fasth_apply(vv, xx, k))(v, x)
        np.testing.assert_allclose(eager, jitted, rtol=1e-5, atol=1e-5)


class TestSvdOps:
    def _setup(self, d=10, m=4, seed=20):
        k1, k2, k3, k4 = keys(seed, 4)
        vu = rand(k1, d, d)
        vv = rand(k2, d, d)
        sigma = 0.75 + 0.5 * jax.random.uniform(k3, (d,), dtype=jnp.float32)
        x = rand(k4, d, m)
        u = ref.product_matrix(vu)
        v = ref.product_matrix(vv)
        w = u @ jnp.diag(sigma) @ v.T
        return vu, vv, sigma, x, w

    def test_svd_apply_matches_materialized(self):
        vu, vv, sigma, x, w = self._setup()
        got = model.svd_apply(vu, vv, sigma, x, 5)
        np.testing.assert_allclose(got, w @ x, rtol=2e-3, atol=2e-3)

    def test_svd_inverse_matches_linalg_inv(self):
        vu, vv, sigma, x, w = self._setup(seed=21)
        got = model.svd_inverse_apply(vu, vv, sigma, x, 5)
        want = jnp.linalg.inv(w) @ x
        np.testing.assert_allclose(got, want, rtol=5e-3, atol=5e-3)

    def test_svd_logdet_matches_slogdet(self):
        vu, vv, sigma, x, w = self._setup(seed=22)
        got = model.svd_logdet(sigma)
        _sign, want = jnp.linalg.slogdet(w)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)

    def test_expm_and_cayley_spectra(self):
        # Spectrum transforms only: check through the σ path.
        sigma = jnp.array([0.5, 1.0, 2.0], dtype=jnp.float32)
        np.testing.assert_allclose(jnp.exp(sigma), jnp.array([jnp.e**0.5, jnp.e, jnp.e**2]),
                                   rtol=1e-5)
        c = (1.0 - sigma) / (1.0 + sigma)
        np.testing.assert_allclose(c, jnp.array([1 / 3, 0.0, -1 / 3]), rtol=1e-5, atol=1e-7)

    def test_svd_layer_step_outputs(self):
        vu, vv, sigma, x, _w = self._setup(seed=23)
        g = rand(keys(24, 1)[0], *x.shape)
        y, dvu, dvv, ds, dx = model.svd_layer_step(vu, vv, sigma, x, g, 5)
        assert y.shape == x.shape
        assert dvu.shape == vu.shape and dvv.shape == vv.shape
        assert ds.shape == sigma.shape and dx.shape == x.shape
        for t in (y, dvu, dvv, ds, dx):
            assert bool(jnp.all(jnp.isfinite(t)))

    def test_gradient_step_matches_ref_grads(self):
        d, k, m = 8, 4, 3
        k1, k2, k3 = keys(25, 3)
        v, x, g = rand(k1, d, d), rand(k2, d, m), rand(k3, d, m)
        a, dv, dx = model.gradient_step(v, x, g, k)
        np.testing.assert_allclose(a, ref.seq_apply(v, x), rtol=1e-3, atol=1e-3)
        dv_ref, dx_ref = jax.grad(
            lambda vv, xx: ref.loss_dot(ref.seq_apply(vv, xx), g), argnums=(0, 1)
        )(v, x)
        np.testing.assert_allclose(dv, dv_ref, rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(dx, dx_ref, rtol=2e-3, atol=2e-3)
