"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

This is the core correctness signal for the compile path — every kernel is
checked against ``ref.py`` over randomized shapes (hypothesis) and the
paper's algebraic invariants (orthogonality, involution, inverse).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import fasth as kernels
from compile.kernels import ref

jax.config.update("jax_enable_x64", False)


def rand(key, *shape):
    return jax.random.normal(key, shape, dtype=jnp.float32)


def keys(seed, n):
    return jax.random.split(jax.random.PRNGKey(seed), n)


# ------------------------------------------------------------- block_apply


class TestBlockApply:
    def test_matches_wy_product(self):
        k1, k2 = keys(0, 2)
        d, k, m = 24, 6, 5
        v = rand(k1, d, k)
        w, y = model.wy_build(v)
        x = rand(k2, d, m)
        got = kernels.block_apply(w, y, x)
        want = ref.wy_build_ref(v) @ x
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    def test_transpose_is_inverse(self):
        k1, k2 = keys(1, 2)
        d, k, m = 16, 4, 3
        w, y = model.wy_build(rand(k1, d, k))
        x = rand(k2, d, m)
        back = kernels.block_apply_transpose(w, y, kernels.block_apply(w, y, x))
        np.testing.assert_allclose(back, x, rtol=1e-3, atol=1e-4)

    @settings(max_examples=20, deadline=None)
    @given(
        d=st.integers(2, 48),
        k=st.integers(1, 8),
        m=st.integers(1, 8),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_sweep(self, d, k, m, seed):
        k = min(k, d)
        k1, k2 = keys(seed, 2)
        v = rand(k1, d, k)
        w, y = model.wy_build(v)
        x = rand(k2, d, m)
        got = kernels.block_apply(w, y, x)
        want = ref.seq_apply(v, x)
        np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)

    def test_zero_vector_block_is_identity(self):
        k2 = keys(2, 1)[0]
        d, k, m = 10, 3, 4
        w, y = model.wy_build(jnp.zeros((d, k)))
        x = rand(k2, d, m)
        np.testing.assert_allclose(kernels.block_apply(w, y, x), x, atol=1e-7)


# ------------------------------------------------------- fasth_apply_fused


class TestFusedKernel:
    @pytest.mark.parametrize("d,k,m", [(12, 3, 4), (32, 8, 5), (16, 16, 2), (8, 1, 3)])
    def test_matches_sequential_ref(self, d, k, m):
        k1, k2 = keys(3, 2)
        v = rand(k1, d, d)
        x = rand(k2, d, m)
        wb, yb = model.build_all_blocks(v, k)
        got = kernels.fasth_apply_fused(wb, yb, x, reverse=True)
        want = ref.seq_apply(v, x)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)

    def test_reverse_false_is_transpose_order(self):
        # Applying blocks 0..nb-1 of the *transposed* blocks gives Uᵀ.
        k1, k2 = keys(4, 2)
        d, k, m = 12, 4, 3
        v = rand(k1, d, d)
        x = rand(k2, d, m)
        wb, yb = model.build_all_blocks(v, k)
        # Pᵀ = I − 2 Y Wᵀ → swap W/Y roles.
        got = kernels.fasth_apply_fused(yb, wb, x, reverse=False)
        want = ref.seq_apply_transpose(v, x)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)

    def test_orthogonality(self):
        # Fused product is an isometry.
        k1, k2 = keys(5, 2)
        d, k, m = 24, 6, 8
        wb, yb = model.build_all_blocks(rand(k1, d, d), k)
        x = rand(k2, d, m)
        y = kernels.fasth_apply_fused(wb, yb, x)
        np.testing.assert_allclose(
            jnp.linalg.norm(y, axis=0), jnp.linalg.norm(x, axis=0), rtol=1e-4
        )

    @settings(max_examples=12, deadline=None)
    @given(
        nb=st.integers(1, 6),
        k=st.integers(1, 6),
        m=st.integers(1, 6),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_block_counts(self, nb, k, m, seed):
        d = max(nb * k, 2)
        k1, k2 = keys(seed, 2)
        v = rand(k1, d, nb * k)
        x = rand(k2, d, m)
        wb, yb = model.build_all_blocks(v, k)
        got = kernels.fasth_apply_fused(wb, yb, x)
        want = ref.seq_apply(v, x)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


# ----------------------------------------------------------------- wy_build


class TestWyBuild:
    @settings(max_examples=16, deadline=None)
    @given(d=st.integers(2, 32), k=st.integers(1, 8), seed=st.integers(0, 2**16))
    def test_lemma1(self, d, k, seed):
        k = min(k, d)
        v = rand(keys(seed, 1)[0], d, k)
        w, y = model.wy_build(v)
        p = jnp.eye(d) - 2.0 * (w @ y.T)
        np.testing.assert_allclose(p, ref.wy_build_ref(v), rtol=5e-4, atol=5e-4)

    def test_vmem_estimate_positive(self):
        assert kernels.vmem_bytes(768, 32, 32) == 4 * (2 * 768 * 32 + 2 * 768 * 32 + 32 * 32)
