"""Layer 2 — the paper's compute graphs in JAX, calling the Pallas kernels.

Implements the SVD reparameterization exactly as the Rust layer does (same
conventions: vector columns, column-major batches) so AOT artifacts and
native kernels are interchangeable:

* :func:`wy_build` — Lemma 1 (compact WY form of k reflections),
* :func:`fasth_apply` — Algorithm 1 forward with a ``jax.custom_vjp``
  whose backward is Algorithm 2 (NOT autodiff through the scan: the point
  of the paper is the hand-scheduled backward with O(d/k + k) sequential
  matmuls, and the custom VJP makes the lowered HLO contain it),
* :func:`svd_apply` / :func:`svd_inverse_apply` / :func:`svd_logdet` /
  :func:`svd_expm_apply` / :func:`svd_cayley_apply` — Table 1's right
  column,
* :func:`gradient_step` — the §4.1 timed unit (fwd + bwd of one
  orthogonal product).

Everything is shape-polymorphic Python; ``aot.py`` instantiates concrete
(d, m, k) triples and lowers to HLO text.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from .kernels import fasth as kernels

_EPS = 1e-30


# --------------------------------------------------------------- WY (Lemma 1)


def _normalize_columns(vblk: jnp.ndarray) -> jnp.ndarray:
    """û_j = v_j/‖v_j‖ columnwise; zero columns stay zero (≡ identity)."""
    ns = jnp.sum(vblk * vblk, axis=0, keepdims=True)
    safe = ns > _EPS
    return jnp.where(safe, vblk / jnp.sqrt(jnp.where(safe, ns, 1.0)), 0.0)


def wy_build(vblk: jnp.ndarray):
    """Lemma 1: W, Y with ``I − 2WYᵀ = H_1 … H_k`` for one block.

    ``vblk`` is ``(d, k)`` (columns = reflection vectors). The recurrence
    appends one column per step — k sequential Householder multiplications,
    ``O(dk²)`` work, exactly the lemma's bound.
    """
    d, k = vblk.shape
    u = _normalize_columns(vblk)

    def body(carry, j):
        w, y = carry  # (d, k), columns ≥ j still zero
        uj = u[:, j]
        t = y.T @ uj  # (k,) — zero beyond built columns
        wj = uj - 2.0 * (w @ t)
        w = lax.dynamic_update_slice(w, wj[:, None], (0, j))
        y = lax.dynamic_update_slice(y, uj[:, None], (0, j))
        return (w, y), None

    init = (jnp.zeros((d, k), vblk.dtype), jnp.zeros((d, k), vblk.dtype))
    (w, y), _ = lax.scan(body, init, jnp.arange(k))
    return w, y


def split_blocks(v: jnp.ndarray, k: int) -> jnp.ndarray:
    """``(d, n) → (nb, d, k)`` column blocks (k must divide n — aot.py
    pads the reflection count; zero columns are identity reflections)."""
    d, n = v.shape
    assert n % k == 0, f"k={k} must divide n={n} (pad with zero vectors)"
    nb = n // k
    return v.T.reshape(nb, k, d).transpose(0, 2, 1)


def build_all_blocks(v: jnp.ndarray, k: int):
    """Step 1 of Algorithm 1: all WY blocks, data-parallel over blocks."""
    return jax.vmap(wy_build)(split_blocks(v, k))


# ------------------------------------------------- FastH fwd/bwd (custom VJP)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def fasth_apply(v: jnp.ndarray, x: jnp.ndarray, k: int) -> jnp.ndarray:
    """``A = H_1 · … · H_n · X`` via FastH (Algorithm 1)."""
    w_blocks, y_blocks = build_all_blocks(v, k)
    return kernels.fasth_apply_fused(w_blocks, y_blocks, x, reverse=True)


def _fasth_fwd(v, x, k):
    return fasth_apply(v, x, k), (v, x)


def _fasth_bwd(k, res, g):
    """Algorithm 2. Residuals are (V, X); blocks and the activation chain
    are recomputed (the chain costs one extra forward — the artifact keeps
    the paper's sequential-depth structure either way, and recomputation
    matches the RevNet-style Eq. 4 spirit)."""
    v, x = res
    d, n = v.shape
    nb = n // k
    w_blocks, y_blocks = build_all_blocks(v, k)
    vblk = split_blocks(v, k)

    # Activation chain A_{nb+1}=X … A_1 (scan over blocks, reversed).
    def fwd_body(a, wy):
        w, y = wy
        a_next = a - 2.0 * (w @ (y.T @ a))
        return a_next, a  # emit the *input* A_{i+1} of this block

    rev = lambda t: jnp.flip(t, axis=0)  # noqa: E731
    _a1, acts_in_rev = lax.scan(fwd_body, x, (rev(w_blocks), rev(y_blocks)))
    # acts_in_rev[j] is the input to block (nb-1-j); re-order to block index.
    acts_in = rev(acts_in_rev)  # acts_in[i] = A_{i+2}… (input of block i)

    # Step 1: grads chain G_i = ∂L/∂A_i; G_{i+1} = P_iᵀ G_i.
    def bwd_body(gcur, wy):
        w, y = wy
        g_next = gcur - 2.0 * (y @ (w.T @ gcur))
        return g_next, gcur  # emit ∂L/∂A_i for block i

    g_last, gouts = lax.scan(bwd_body, g, (w_blocks, y_blocks))
    dx = g_last  # ∂L/∂X = ∂L/∂A_{nb+1}

    # Step 2: per-block subproblems in parallel (vmap): Eq. 4 + Eq. 5.
    def block_grad(vb, a_out_grad, a_in):
        # Recompute Â chain inside the block: Â_{j+1} = Ĥ_j Â_j, starting
        # from the block *output* Â_1 = P_i·A_{i+1}. We reconstruct Â_1 by
        # one block apply (cheaper than storing it): this keeps residual
        # memory at O(d·m·nb) like the paper's Remark.
        def refl(aa, vj):
            ns = jnp.dot(vj, vj)
            coef = jnp.where(ns > _EPS, 2.0 / jnp.where(ns > _EPS, ns, 1.0), 0.0)
            return aa - coef * jnp.outer(vj, vj @ aa)

        # Â_1 (the block output) from A_{i+1}: apply the block's reflections
        # rightmost-first.
        def fwd_in_block(aa, j):
            return refl(aa, vb[:, k - 1 - j]), None

        a1, _ = lax.scan(fwd_in_block, a_in, jnp.arange(k))

        def body(carry, j):
            a_cur, g_cur = carry
            vj = vb[:, j]
            a_next = refl(a_cur, vj)  # Â_{j+1}
            # Eq. 5 with input Â_{j+1} and output-grad ∂L/∂Â_j.
            ns = jnp.dot(vj, vj)
            safe_ns = jnp.where(ns > _EPS, ns, 1.0)
            alpha = vj @ a_next  # (m,)
            gamma = vj @ g_cur
            s = jnp.dot(alpha, gamma)
            c = 2.0 / safe_ns
            gv = -c * (g_cur @ alpha + a_next @ gamma - c * s * vj)
            gv = jnp.where(ns > _EPS, gv, 0.0)
            g_next = refl(g_cur, vj)
            return (a_next, g_next), gv

        (_af, _gf), gvs = lax.scan(body, (a1, a_out_grad), jnp.arange(k))
        return gvs  # (k, d)

    gvs = jax.vmap(block_grad)(vblk, gouts, acts_in)  # (nb, k, d)
    dv = gvs.reshape(n, d).T  # column i = ∂L/∂v_i
    return dv, dx


fasth_apply.defvjp(_fasth_fwd, _fasth_bwd)


def fasth_apply_transpose(v: jnp.ndarray, x: jnp.ndarray, k: int) -> jnp.ndarray:
    """``(H_1…H_n)ᵀ·X`` — reversed column order through the same path."""
    return fasth_apply(jnp.flip(v, axis=1), x, k)


# ------------------------------------------------------------- SVD layer ops


def svd_apply(vu, vv, sigma, x, k: int):
    """``W·X = U·(Σ·(Vᵀ·X))`` — Table 1's factored weight applied."""
    x1 = fasth_apply_transpose(vv, x, k)
    x2 = sigma[:, None] * x1
    return fasth_apply(vu, x2, k)


def svd_inverse_apply(vu, vv, sigma, x, k: int):
    """``W⁻¹·X = V·(Σ⁻¹·(Uᵀ·X))`` — O(d²m) instead of an O(d³) inverse."""
    y1 = fasth_apply_transpose(vu, x, k)
    y2 = y1 / sigma[:, None]
    return fasth_apply(vv, y2, k)


def svd_logdet(sigma):
    """``log|det W| = Σ log|σ_i|`` — O(d) (Table 1, determinant row)."""
    return jnp.sum(jnp.log(jnp.abs(sigma)))


def svd_expm_apply(vu, vv, sigma, x, k: int):
    """``U·e^Σ·Vᵀ·X`` (two-factor upper-bound form, §8.3)."""
    return svd_apply(vu, vv, jnp.exp(sigma), x, k)


def svd_cayley_apply(vu, vv, sigma, x, k: int):
    """``U·(I−Σ)(I+Σ)⁻¹·Vᵀ·X`` (two-factor upper-bound form, §8.3)."""
    return svd_apply(vu, vv, (1.0 - sigma) / (1.0 + sigma), x, k)


# ----------------------------------------------------------- timed step units


def gradient_step(v, x, g, k: int):
    """The §4.1 unit: forward ``A = H_1…H_d·X`` plus gradients wrt V and X
    under the dummy upstream gradient G. Returns ``(A, ∂L/∂V, ∂L/∂X)``."""
    def loss(vv, xx):
        return jnp.sum(fasth_apply(vv, xx, k) * g)

    a = fasth_apply(v, x, k)
    dv, dx = jax.grad(loss, argnums=(0, 1))(v, x)
    return a, dv, dx


def svd_layer_step(vu, vv, sigma, x, g, k: int):
    """Full LinearSVD fwd+bwd (the serving/training artifact)."""
    def loss(vu_, vv_, s_, x_):
        return jnp.sum(svd_apply(vu_, vv_, s_, x_, k) * g)

    y = svd_apply(vu, vv, sigma, x, k)
    dvu, dvv, ds, dx = jax.grad(loss, argnums=(0, 1, 2, 3))(vu, vv, sigma, x)
    return y, dvu, dvv, ds, dx
