"""Layer 1 — Pallas kernels for the FastH hot loop.

The paper's CUDA kernel is re-thought for TPU (DESIGN.md
§Hardware-Adaptation): the WY block application

    A ← A − 2·W_i·(Y_iᵀ·A)

is two MXU-shaped GEMMs (``(k×d)·(d×m)`` and ``(d×k)·(k×m)``) whose
operands are staged into VMEM by BlockSpec — the role the paper's
threadblock/shared-memory tiling played on the RTX 2080 Ti. The block
size k is exactly the VMEM tile parameter (§3.3's time/parallelism knob).

Two kernels:

* :func:`block_apply` — one WY block applied to a batch (grid = (),
  everything resident in VMEM). Used inside the L2 scan.
* :func:`fasth_apply_fused` — the whole product ``P_1 … P_nb · X`` in one
  ``pallas_call`` with ``grid=(nb,)``: the output ref is *revisited* by
  every grid step (its index map is constant), which on TPU keeps the
  running batch ``A`` resident in VMEM across the sequential block loop —
  the double-buffered HBM↔VMEM schedule only streams the (d×k) W/Y panels.

Pallas runs with ``interpret=True`` everywhere: real-TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute; interpret mode
lowers to plain HLO so the AOT artifacts run on the Rust CPU runtime.
Real-TPU performance is *estimated* from the BlockSpec footprint in
EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True  # CPU PJRT cannot run Mosaic custom-calls; see module docs.


def _block_apply_kernel(w_ref, y_ref, x_ref, o_ref):
    """o = x − 2·W·(Yᵀ·x) — the two fused MXU GEMMs."""
    t = jnp.dot(y_ref[...].T, x_ref[...])  # (k, m), reduction over d
    o_ref[...] = x_ref[...] - 2.0 * jnp.dot(w_ref[...], t)


@jax.jit
def block_apply(w: jnp.ndarray, y: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Apply one WY block ``P = I − 2WYᵀ`` to ``x`` (all VMEM-resident).

    Shapes: ``w, y: (d, k)``, ``x: (d, m)`` → ``(d, m)``.
    VMEM footprint: ``(2dk + 2dm + km)·4`` bytes.
    """
    d, m = x.shape
    return pl.pallas_call(
        _block_apply_kernel,
        out_shape=jax.ShapeDtypeStruct((d, m), x.dtype),
        interpret=INTERPRET,
    )(w, y, x)


def _block_apply_transpose_kernel(w_ref, y_ref, x_ref, o_ref):
    """o = x − 2·Y·(Wᵀ·x) — the Eq. 3 transpose step ``Pᵀ·x``."""
    t = jnp.dot(w_ref[...].T, x_ref[...])
    o_ref[...] = x_ref[...] - 2.0 * jnp.dot(y_ref[...], t)


@jax.jit
def block_apply_transpose(w: jnp.ndarray, y: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Apply ``Pᵀ = I − 2YWᵀ`` to ``x``."""
    d, m = x.shape
    return pl.pallas_call(
        _block_apply_transpose_kernel,
        out_shape=jax.ShapeDtypeStruct((d, m), x.dtype),
        interpret=INTERPRET,
    )(w, y, x)


def _fasth_fused_kernel(w_ref, y_ref, x_ref, o_ref):
    """Grid step g applies block ``nb−1−g`` (P_nb first, P_1 last) to the
    VMEM-resident running batch held in ``o_ref``."""
    g = pl.program_id(0)

    @pl.when(g == 0)
    def _init():
        o_ref[...] = x_ref[...]

    a = o_ref[...]
    t = jnp.dot(y_ref[0].T, a)
    o_ref[...] = a - 2.0 * jnp.dot(w_ref[0], t)


@functools.partial(jax.jit, static_argnames=("reverse",))
def fasth_apply_fused(
    w_blocks: jnp.ndarray, y_blocks: jnp.ndarray, x: jnp.ndarray, reverse: bool = True
) -> jnp.ndarray:
    """The full FastH Step-2 loop ``A = P_1·(P_2·(…(P_nb·X)))`` as one
    Pallas call.

    Shapes: ``w_blocks, y_blocks: (nb, d, k)``, ``x: (d, m)``.
    ``reverse=True`` applies block nb−1 first (the forward product order);
    ``reverse=False`` applies block 0 first (used for ``Uᵀ`` chains whose
    blocks were pre-transposed by the caller).

    HBM↔VMEM schedule expressed by the BlockSpecs: per grid step one
    ``(d, k)`` W panel + one ``(d, k)`` Y panel stream in; ``X`` streams in
    once (step 0); the output block index is constant so ``A`` stays
    resident in VMEM for all nb steps.
    """
    nb, d, k = w_blocks.shape
    m = x.shape[1]
    if reverse:
        idx = lambda g: (nb - 1 - g, 0, 0)  # noqa: E731
    else:
        idx = lambda g: (g, 0, 0)  # noqa: E731
    return pl.pallas_call(
        _fasth_fused_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, d, k), idx),
            pl.BlockSpec((1, d, k), idx),
            pl.BlockSpec((d, m), lambda g: (0, 0)),
        ],
        out_specs=pl.BlockSpec((d, m), lambda g: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((d, m), x.dtype),
        interpret=INTERPRET,
    )(w_blocks, y_blocks, x)


def vmem_bytes(d: int, k: int, m: int, dtype_bytes: int = 4) -> int:
    """VMEM working set of one fused grid step (W, Y panels + A + X + T).

    Used by the §Perf roofline estimate: the working set must fit the
    ~16 MiB TPU VMEM; k trades panel size against sequential depth d/k.
    """
    return dtype_bytes * (2 * d * k + 2 * d * m + k * m)
