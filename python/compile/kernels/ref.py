"""Pure-jnp reference oracle for the FastH kernels.

Everything here is deliberately naive and definitional — explicit
Householder matrices, Python loops — so the Pallas kernels and the blocked
model code in ``model.py`` can be validated against an implementation whose
correctness is obvious. Conventions match the paper and the Rust layer:

* ``V`` is ``d×n`` with **column i** holding the (unnormalized) Householder
  vector ``v_{i+1}``; a zero column encodes the identity reflection,
* the forward product is ``A = H_1 · H_2 · … · H_n · X`` (so ``H_n`` is
  applied to ``X`` first),
* mini-batches are column-major: ``X ∈ R^{d×m}``.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = [
    "householder_matrix",
    "product_matrix",
    "seq_apply",
    "seq_apply_transpose",
    "wy_build_ref",
    "loss_dot",
]

_EPS = 1e-30


def householder_matrix(v: jnp.ndarray) -> jnp.ndarray:
    """Explicit ``H = I − 2 v vᵀ / ‖v‖²`` (identity for ``v = 0``)."""
    d = v.shape[0]
    ns = jnp.dot(v, v)
    eye = jnp.eye(d, dtype=v.dtype)
    outer = jnp.outer(v, v)
    return jnp.where(ns > _EPS, eye - (2.0 / jnp.where(ns > _EPS, ns, 1.0)) * outer, eye)


def product_matrix(vs: jnp.ndarray) -> jnp.ndarray:
    """Materialize ``U = H_1 · … · H_n`` from ``d×n`` vector columns."""
    d, n = vs.shape
    u = jnp.eye(d, dtype=vs.dtype)
    for i in range(n):
        u = u @ householder_matrix(vs[:, i])
    return u


def seq_apply(vs: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """``H_1 · … · H_n · X`` one reflection at a time (rightmost first)."""
    a = x
    for i in reversed(range(vs.shape[1])):
        v = vs[:, i]
        ns = jnp.dot(v, v)
        coef = jnp.where(ns > _EPS, 2.0 / jnp.where(ns > _EPS, ns, 1.0), 0.0)
        a = a - coef * jnp.outer(v, v @ a)
    return a


def seq_apply_transpose(vs: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """``(H_1 … H_n)ᵀ · X = H_n · … · H_1 · X``."""
    a = x
    for i in range(vs.shape[1]):
        v = vs[:, i]
        ns = jnp.dot(v, v)
        coef = jnp.where(ns > _EPS, 2.0 / jnp.where(ns > _EPS, ns, 1.0), 0.0)
        a = a - coef * jnp.outer(v, v @ a)
    return a


def wy_build_ref(vblk: jnp.ndarray) -> jnp.ndarray:
    """The WY *product matrix* ``P = H_1 … H_k`` for a block of vectors —
    the object Lemma 1 promises ``I − 2WYᵀ`` equals."""
    return product_matrix(vblk)


def loss_dot(a: jnp.ndarray, g: jnp.ndarray) -> jnp.ndarray:
    """Test loss ``<G, A>`` used for gradient cross-checks."""
    return jnp.sum(a * g)
