"""AOT compile path: lower the L2 graphs to HLO **text** + manifest.

Python runs exactly once (``make artifacts``); the Rust runtime loads the
HLO text via ``HloModuleProto::from_text_file`` and never calls back into
Python. HLO *text* (not ``.serialize()``) is the interchange format: jax
≥ 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids (see
/opt/xla-example/README.md).

Usage:
    python -m compile.aot --out-dir ../artifacts [--sizes 64,128,256]

Artifacts (per size d, batch m = 32, k = max(32, ceil(sqrt(d)))):
    orthogonal_apply_{d}   V(d,d), X(d,m)                  -> A(d,m)
    gradient_step_{d}      V(d,d), X(d,m), G(d,m)          -> (A, dV, dX)
    svd_apply_{d}          Vu, Vv, sigma(d), X             -> Y
    svd_inverse_{d}        Vu, Vv, sigma(d), X             -> Y
    svd_layer_step_{d}     Vu, Vv, sigma, X, G             -> (Y, dVu, dVv, dS, dX)
plus ``manifest.json`` describing name → file, input/output shapes, k.
"""

from __future__ import annotations

import argparse
import json
import math
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

DEFAULT_SIZES = [64, 128, 256]
BATCH = 32


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def pick_k(d: int, m: int = BATCH) -> int:
    """§3.3 heuristic block size: k = max(m, √d), rounded to divide d."""
    k = max(m, int(math.ceil(math.sqrt(d))))
    k = min(k, d)
    while d % k != 0:  # shrink until it divides (d is a multiple of 64 here)
        k -= 1
    return max(k, 1)


def entries(d: int):
    """(name, fn, example_args) for every artifact at size d."""
    m = BATCH
    k = pick_k(d, m)
    f32 = jnp.float32
    v = jax.ShapeDtypeStruct((d, d), f32)
    x = jax.ShapeDtypeStruct((d, m), f32)
    g = jax.ShapeDtypeStruct((d, m), f32)
    s = jax.ShapeDtypeStruct((d,), f32)

    def shapes(*specs):
        return [list(sp.shape) for sp in specs]

    return k, [
        {
            "name": f"orthogonal_apply_{d}",
            "fn": lambda vv, xx: (model.fasth_apply(vv, xx, k),),
            "args": (v, x),
            "inputs": shapes(v, x),
            "outputs": [[d, m]],
        },
        {
            "name": f"gradient_step_{d}",
            "fn": lambda vv, xx, gg: model.gradient_step(vv, xx, gg, k),
            "args": (v, x, g),
            "inputs": shapes(v, x, g),
            "outputs": [[d, m], [d, d], [d, m]],
        },
        {
            "name": f"svd_apply_{d}",
            "fn": lambda vu, vv, ss, xx: (model.svd_apply(vu, vv, ss, xx, k),),
            "args": (v, v, s, x),
            "inputs": shapes(v, v, s, x),
            "outputs": [[d, m]],
        },
        {
            "name": f"svd_inverse_{d}",
            "fn": lambda vu, vv, ss, xx: (model.svd_inverse_apply(vu, vv, ss, xx, k),),
            "args": (v, v, s, x),
            "inputs": shapes(v, v, s, x),
            "outputs": [[d, m]],
        },
        {
            "name": f"svd_layer_step_{d}",
            "fn": lambda vu, vv, ss, xx, gg: model.svd_layer_step(vu, vv, ss, xx, gg, k),
            "args": (v, v, s, x, g),
            "inputs": shapes(v, v, s, x, g),
            "outputs": [[d, m], [d, d], [d, d], [d], [d, m]],
        },
    ]


def build(out_dir: str, sizes: list[int]) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"batch": BATCH, "entries": []}
    for d in sizes:
        k, ents = entries(d)
        for ent in ents:
            lowered = jax.jit(ent["fn"]).lower(*ent["args"])
            text = to_hlo_text(lowered)
            fname = f"{ent['name']}.hlo.txt"
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(text)
            manifest["entries"].append(
                {
                    "name": ent["name"],
                    "file": fname,
                    "d": d,
                    "m": BATCH,
                    "k": k,
                    "inputs": ent["inputs"],
                    "outputs": ent["outputs"],
                }
            )
            print(f"  wrote {fname} ({len(text)} chars, k={k})", file=sys.stderr)
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="(compat) single-file sentinel path")
    ap.add_argument(
        "--sizes",
        default=",".join(str(s) for s in DEFAULT_SIZES),
        help="comma-separated d values",
    )
    args = ap.parse_args()
    sizes = [int(s) for s in args.sizes.split(",") if s]
    out_dir = args.out_dir
    if args.out:
        out_dir = os.path.dirname(os.path.abspath(args.out)) or out_dir
    manifest = build(out_dir, sizes)
    if args.out:
        # Makefile stamp-file compatibility: the first artifact doubles as
        # the make target; ensure it exists.
        first = os.path.join(out_dir, manifest["entries"][0]["file"])
        assert os.path.exists(first)
    print(f"wrote {len(manifest['entries'])} artifacts to {out_dir}", file=sys.stderr)


if __name__ == "__main__":
    main()
